//! Fault-injection integration tests: deterministic device/link chaos on
//! the virtual clock, and the recovery machinery that keeps the fleet
//! serving through it — replica failover between pin windows, the
//! miss/fault degradation waterfall, retry/backoff on lost transfers,
//! and deadline-bounded drops. The acceptance contract: a replicated
//! 4-device ring rides out a mid-sweep device failure with every request
//! completed and zero dropped experts, byte-identically across thread
//! counts; fault-free runs are byte-identical to runs that never heard
//! of the fault subsystem.

use std::sync::{Arc, Mutex};

use buddymoe::config::{ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::eval::{
    build_requests, engine_with_config, profile_model, warm_rank_from_profile, TableSettings,
};
use buddymoe::fault::{FaultEvent, FaultKind, FaultPlan};
use buddymoe::model::EngineOptions;
use buddymoe::server::Server;
use buddymoe::topology::{PlacementKind, TopologyKind};
use buddymoe::util::clock::ClockMode;
use buddymoe::util::par;
use buddymoe::weights::{ExpertKey, WeightStore};

/// `par::set_threads` is a process-global override and the test harness
/// runs tests concurrently; serialize every test that drives it.
static PAR_LOCK: Mutex<()> = Mutex::new(());

fn par_lock() -> std::sync::MutexGuard<'static, ()> {
    PAR_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    (cfg, store)
}

fn fleet_scfg(n_devices: usize, placement: PlacementKind) -> ServingConfig {
    let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
    scfg.cache_rate = 0.5;
    scfg.n_devices = n_devices;
    scfg.topology = TopologyKind::Ring;
    scfg.placement = placement;
    scfg.kappa = 0.25;
    scfg
}

fn ev(at_s: f64, kind: FaultKind) -> FaultEvent {
    FaultEvent { at_s, kind }
}

/// Serve the shared eval workload to completion; panics if any request
/// fails to complete (the fleet must never wedge under faults).
fn serve(cfg: &ModelConfig, store: Arc<WeightStore>, scfg: ServingConfig) -> Server {
    let pc = profile_model(cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
    let engine = engine_with_config(cfg, store, &pc, &warm, scfg, opts).unwrap();
    let mut server = Server::new(engine);
    let settings = TableSettings {
        cache_rate: 0.5,
        n_easy: 3,
        n_hard: 3,
        max_new: 4,
        seed: 42,
        clock: ClockMode::Virtual,
    };
    let reqs = build_requests(cfg, &settings);
    let n = reqs.len();
    let responses = server.run_offline(reqs).unwrap();
    assert_eq!(responses.len(), n, "every request must complete");
    server
}

/// The fault/recovery accounting a deterministic replay must reproduce
/// exactly.
fn fault_fingerprint(server: &Server) -> Vec<(&'static str, u64)> {
    let c = &server.engine.counters;
    vec![
        ("substitutions", c.get("substitutions")),
        ("fetches", c.get("fetches")),
        ("routed_slots", c.get("routed_slots")),
        ("dropped_slots", c.get("dropped_slots")),
        ("device_failovers", c.get("device_failovers")),
        ("failover_rerouted", c.get("failover_rerouted")),
        ("failover_rehomed", c.get("failover_rehomed")),
        ("failover_restored", c.get("failover_restored")),
        ("emergency_promotions", c.get("emergency_promotions")),
        ("waterfall_replica_hits", c.get("waterfall_replica_hits")),
        ("waterfall_buddy_subs", c.get("waterfall_buddy_subs")),
        ("waterfall_retried_fetches", c.get("waterfall_retried_fetches")),
        ("waterfall_transient_rescues", c.get("waterfall_transient_rescues")),
        ("waterfall_drops", c.get("waterfall_drops")),
        ("transfer_retries", c.get("transfer_retries")),
        ("subs_in_fault_window", c.get("subs_in_fault_window")),
        ("subs_outside_fault_window", c.get("subs_outside_fault_window")),
        ("degraded_requests", server.metrics.degraded_requests),
        ("clock_ns", server.engine.clock().now().as_nanos() as u64),
    ]
}

#[test]
fn fault_free_runs_ignore_retry_tuning_and_empty_plans() {
    // The byte-identity contract: an empty FaultPlan plus non-default
    // retry/backoff knobs must leave a fault-free fleet timeline exactly
    // where it was — backoff jitter is only ever drawn on a second
    // re-issue, which never happens without injected chaos.
    let (cfg, store) = setup();
    let baseline = {
        let server = serve(&cfg, store.clone(), fleet_scfg(2, PlacementKind::LayerStriped));
        let out = fault_fingerprint(&server);
        server.engine.shutdown();
        out
    };
    let tuned = {
        let mut scfg = fleet_scfg(2, PlacementKind::LayerStriped);
        scfg.fault_plan = FaultPlan::empty();
        scfg.transfer_max_retries = 9;
        scfg.transfer_backoff_base_s = 0.05;
        let server = serve(&cfg, store, scfg);
        let out = fault_fingerprint(&server);
        server.engine.shutdown();
        out
    };
    assert_eq!(baseline, tuned, "empty plan + tuning knobs must not perturb the timeline");
    let zeros: Vec<&str> = baseline
        .iter()
        .filter(|(k, v)| k.starts_with("waterfall") && *v > 0)
        .map(|(k, _)| *k)
        .collect();
    assert!(zeros.is_empty(), "waterfall arms fired without faults: {zeros:?}");
}

#[test]
fn permanent_device_down_rehomes_every_expert_and_serves_all_requests() {
    // Single-homed fleet: a permanent device failure must displace every
    // expert homed there onto survivors, and with no transfer deadline
    // the waterfall is lossless — zero dropped slots.
    let (cfg, store) = setup();
    let mut scfg = fleet_scfg(4, PlacementKind::LayerStriped);
    scfg.fault_plan = FaultPlan::from_events(vec![ev(
        0.001,
        FaultKind::DeviceDown { device: 1, down_s: None },
    )]);
    let server = serve(&cfg, store, scfg);

    let c = &server.engine.counters;
    assert!(c.get("device_failovers") >= 1, "the down event must trigger failover");
    assert!(c.get("failover_rehomed") > 0, "striped experts on device 1 must rehome");
    assert_eq!(c.get("dropped_slots"), 0, "no deadline means a lossless waterfall");
    assert_eq!(c.get("waterfall_drops"), 0);
    assert_eq!(c.get("failover_restored"), 0, "a permanent failure never restores");
    // Every home set now avoids the dead device.
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let homes = server.engine.placement().homes(ExpertKey::new(l, e)).to_vec();
            assert!(!homes.contains(&1), "layer {l} expert {e} still homed on dead device");
            assert!(!homes.is_empty(), "layer {l} expert {e} lost all homes");
        }
    }
    // Window accounting is conservation-exact: the down window is
    // [1 ms, inf), so every substitution lands in exactly one bucket.
    assert_eq!(
        c.get("subs_in_fault_window") + c.get("subs_outside_fault_window"),
        c.get("substitutions"),
        "window split must partition the substitution count"
    );
    server.engine.shutdown();
}

#[test]
fn replica_survivors_serve_displaced_hot_experts_in_place() {
    // Waterfall arm 1: with rf = 2 on two devices the hot experts are
    // homed on both, so downing device 1 leaves them resident on device
    // 0 — they keep serving as replica hits, with no refetch and no
    // substitution of the hot set.
    let (cfg, store) = setup();
    let mut scfg = fleet_scfg(2, PlacementKind::Popularity);
    scfg.topology = TopologyKind::FullyConnected;
    scfg.replication_factor = 2;
    scfg.replan_interval_steps = 0; // isolate failover from the replanner
    scfg.fault_plan = FaultPlan::from_events(vec![ev(
        0.001,
        FaultKind::DeviceDown { device: 1, down_s: None },
    )]);
    let server = serve(&cfg, store, scfg);

    let c = &server.engine.counters;
    assert!(c.get("device_failovers") >= 1);
    assert!(
        c.get("waterfall_replica_hits") > 0,
        "hot displaced experts must be served from the surviving replica"
    );
    assert_eq!(c.get("dropped_slots"), 0);
    assert!(server.metrics.degraded_requests >= 1, "replica-hit steps are degraded");
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let homes = server.engine.placement().homes(ExpertKey::new(l, e)).to_vec();
            assert!(!homes.contains(&1), "layer {l} expert {e} still homed on dead device");
        }
    }
    server.engine.shutdown();
}

#[test]
fn lost_in_flight_transfers_surface_as_retried_fetches() {
    // Waterfall arm 3: losing in-flight host transfers mid-prefill forces
    // re-issues, surfaced as retried fetches and a degraded annotation —
    // never as silent stalls or drops.
    let (cfg, store) = setup();
    let mut scfg = ServingConfig::default().preset("original").unwrap();
    scfg.cache_rate = 0.5;
    scfg.prefetch = PrefetchKind::None; // losses must land on demand fetches
    scfg.fault_plan = FaultPlan::from_events(vec![
        ev(0.0003, FaultKind::LoseInFlight { device: 0 }),
        ev(0.0009, FaultKind::LoseInFlight { device: 0 }),
        ev(0.0015, FaultKind::LoseInFlight { device: 0 }),
    ]);
    let server = serve(&cfg, store, scfg);

    let c = &server.engine.counters;
    assert!(c.get("transfer_retries") > 0, "losses on a saturated link must retry");
    assert!(c.get("waterfall_retried_fetches") > 0);
    assert_eq!(c.get("dropped_slots"), 0, "retries recover everything without a deadline");
    assert!(server.metrics.degraded_requests >= 1, "retried steps are degraded");
    server.engine.shutdown();
}

#[test]
fn deadline_drops_slots_when_the_host_link_stalls() {
    // Waterfall arm 4: under a hard per-transfer deadline a stalled host
    // link exhausts retry-then-refetch and drops the slot — bounded
    // latency traded for fidelity, with exact drop accounting.
    let (cfg, store) = setup();
    let mut scfg = ServingConfig::default().preset("original").unwrap();
    scfg.cache_rate = 0.5;
    scfg.prefetch = PrefetchKind::None; // isolate the demand-fetch deadline path
    scfg.transfer_deadline_s = 0.005;
    scfg.fault_plan = FaultPlan::from_events(vec![ev(
        0.0,
        FaultKind::HostStall { device: 0, duration_s: 1e6 },
    )]);
    let server = serve(&cfg, store, scfg);

    let c = &server.engine.counters;
    assert!(c.get("dropped_slots") > 0, "a stalled link under deadline must drop");
    assert!(c.get("waterfall_drops") > 0);
    assert!(
        c.get("dropped_slots") >= c.get("waterfall_drops"),
        "each dropped expert covers at least one routed slot"
    );
    assert_eq!(
        c.get("drops_in_fault_window") + c.get("drops_outside_fault_window"),
        c.get("dropped_slots"),
        "window split must partition the drop count"
    );
    assert!(c.get("drops_in_fault_window") > 0, "the stall window spans the whole run");
    assert!(server.metrics.degraded_requests >= 1, "dropped steps are degraded");
    server.engine.shutdown();
}

#[test]
fn faulted_runs_are_deterministic_per_seed() {
    // Chaos replays: the whole fault pipeline (event application, retry
    // jitter, failover ordering, waterfall arms) lives on the virtual
    // clock and seeded RNG streams, so the same seed must reproduce every
    // counter and the final clock exactly.
    let (cfg, store) = setup();
    let run = |store: Arc<WeightStore>| {
        let mut scfg = fleet_scfg(4, PlacementKind::Popularity);
        scfg.replication_factor = 2;
        scfg.fault_plan = FaultPlan::from_events(vec![
            ev(0.001, FaultKind::DeviceDown { device: 1, down_s: Some(0.005) }),
            ev(0.004, FaultKind::LoseInFlight { device: 0 }),
        ]);
        let server = serve(&cfg, store, scfg);
        let out = fault_fingerprint(&server);
        server.engine.shutdown();
        out
    };
    let a = run(store.clone());
    let b = run(store);
    assert_eq!(a, b, "same seed must replay the faulted timeline exactly");
}

#[test]
fn replicated_ring_survives_device_down_across_thread_counts() {
    // The acceptance e2e: a 4-device ring with replication_factor = 2
    // takes a mid-sweep device failure (down at 1 ms, back at 6 ms),
    // completes every request with zero dropped experts, and replays
    // byte-identically at PALLAS_THREADS 1 and 4.
    let _serialize = par_lock();
    let (cfg, store) = setup();
    let run = |store: Arc<WeightStore>, threads: usize| {
        par::set_threads(threads);
        let mut scfg = fleet_scfg(4, PlacementKind::Popularity);
        scfg.replication_factor = 2;
        scfg.fault_plan = FaultPlan::from_events(vec![ev(
            0.001,
            FaultKind::DeviceDown { device: 1, down_s: Some(0.005) },
        )]);
        let server = serve(&cfg, store, scfg);
        let out = fault_fingerprint(&server);
        server.engine.shutdown();
        par::set_threads(0);
        out
    };
    let one = run(store.clone(), 1);
    let four = run(store, 4);
    assert_eq!(one, four, "thread count must never change the faulted timeline");

    let get = |k: &str| one.iter().find(|(n, _)| *n == k).unwrap().1;
    assert!(get("device_failovers") >= 1, "the failure must land mid-sweep");
    assert!(
        get("failover_rerouted") + get("failover_rehomed") > 0,
        "experts homed on the dead device must be displaced"
    );
    assert_eq!(get("dropped_slots"), 0, "replicated fleet survives with zero drops");
    assert_eq!(get("waterfall_drops"), 0);
}
