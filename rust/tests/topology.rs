//! Multi-device expert-parallel integration tests: the fleet serves
//! end-to-end on the virtual clock, warm-up respects per-device budgets,
//! fleet-wide residency is the union of per-device residency, runs are
//! deterministic per seed, and replication invariants hold — replicated
//! experts land on exactly their home set at warm-up, eviction never
//! strips a hot expert below its replication intent, and replicated
//! fleets (contended peer links included) replay byte-identically per
//! seed. (The ψ/κ same-device-preference contract is unit-tested next to
//! the substitution engine; the single-device degenerate case is covered
//! by the unchanged golden tests.)

use std::sync::Arc;

use buddymoe::config::{ModelConfig, ServingConfig};
use buddymoe::eval::{
    build_requests, engine_with_config, profile_model, warm_rank_from_profile, TableSettings,
};
use buddymoe::model::EngineOptions;
use buddymoe::server::Server;
use buddymoe::topology::{PlacementKind, TopologyKind};
use buddymoe::util::clock::ClockMode;
use buddymoe::weights::{ExpertKey, WeightStore};

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    (cfg, store)
}

fn fleet_scfg(n_devices: usize, placement: PlacementKind) -> ServingConfig {
    let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
    scfg.cache_rate = 0.5;
    scfg.n_devices = n_devices;
    scfg.placement = placement;
    scfg.kappa = 0.25; // κ live: ψ sees real hop counts
    scfg
}

fn serve(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    scfg: ServingConfig,
) -> (Server, usize) {
    let pc = profile_model(cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
    let engine = engine_with_config(cfg, store, &pc, &warm, scfg, opts).unwrap();
    let mut server = Server::new(engine);
    let settings = TableSettings {
        cache_rate: 0.5,
        n_easy: 3,
        n_hard: 3,
        max_new: 4,
        seed: 42,
        clock: ClockMode::Virtual,
    };
    let reqs = build_requests(cfg, &settings);
    let n = reqs.len();
    let responses = server.run_offline(reqs).unwrap();
    assert_eq!(responses.len(), n, "every request must complete");
    (server, n)
}

#[test]
fn two_device_fleet_serves_end_to_end() {
    let (cfg, store) = setup();
    let (server, _) = serve(&cfg, store, fleet_scfg(2, PlacementKind::LayerStriped));

    server.engine.transfer_handle().with_state(|st| {
        assert_eq!(st.n_devices(), 2);
        for l in 0..cfg.n_layers {
            // Warm-up and serving never oversubscribe a device's budget.
            for (d, dev) in st.devices.iter().enumerate() {
                assert!(
                    dev.cache.gpu_count(l) <= dev.cache.capacity_per_layer(),
                    "device {d} layer {l} over budget"
                );
            }
            // Fleet-wide residency is the union of per-device residency.
            let mask = st.residency_mask(l);
            let resident = mask.iter().filter(|&&m| m).count();
            let per_device: usize = st.devices.iter().map(|dev| dev.cache.gpu_count(l)).sum();
            assert_eq!(resident, per_device, "layer {l} mask/union mismatch");
        }
        // Host traffic happened somewhere, and the fleet aggregate equals
        // the per-device sum.
        let total = st.pcie_stats();
        let summed: u64 = st.devices.iter().map(|d| d.pcie.stats.total_transfers()).sum();
        assert_eq!(total.total_transfers(), summed);
        assert!(total.total_transfers() > 0, "cache_rate 0.5 must miss or prefetch");
    });
    server.engine.shutdown();
}

#[test]
fn four_device_popularity_fleet_serves_end_to_end() {
    let (cfg, store) = setup();
    let (server, _) = serve(&cfg, store, fleet_scfg(4, PlacementKind::Popularity));
    server.engine.transfer_handle().with_state(|st| {
        assert_eq!(st.n_devices(), 4);
        // Popularity placement deals every layer's experts evenly.
        for l in 0..cfg.n_layers {
            for d in 0..4 {
                assert_eq!(
                    st.placement.experts_on(l, d),
                    cfg.n_experts / 4,
                    "layer {l} device {d} share"
                );
            }
        }
    });
    server.engine.shutdown();
}

#[test]
fn fleet_runs_are_deterministic_per_seed() {
    let (cfg, store) = setup();
    let run = |store: Arc<WeightStore>| {
        let (server, _) = serve(&cfg, store, fleet_scfg(2, PlacementKind::LayerStriped));
        let out = (
            server.engine.counters.get("substitutions"),
            server.engine.counters.get("fetches"),
            server.engine.counters.get("cross_device_subs"),
            server.engine.counters.get("peer_hops"),
            server.engine.clock().now(),
        );
        server.engine.shutdown();
        out
    };
    let a = run(store.clone());
    let b = run(store);
    assert_eq!(a, b, "same seed must reproduce the fleet timeline exactly");
}

// ---------------------------------------------------------------------
// Replication invariants
// ---------------------------------------------------------------------

fn replicated_scfg(n_devices: usize, rf: usize, topology: TopologyKind) -> ServingConfig {
    let mut scfg = fleet_scfg(n_devices, PlacementKind::Popularity);
    scfg.topology = topology;
    scfg.replication_factor = rf;
    scfg
}

#[test]
fn replicated_experts_resident_on_exactly_their_home_set_after_warmup() {
    // Warm-up must place every replicated expert on each of its homes and
    // nowhere else — before any traffic runs.
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let mut scfg = replicated_scfg(2, 2, TopologyKind::FullyConnected);
    scfg.replan_interval_steps = 0;
    let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
    let engine = engine_with_config(&cfg, store, &pc, &warm, scfg, opts).unwrap();

    assert!(engine.placement().is_replicated(), "rf = 2 must replicate");
    let mut replicated = 0usize;
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let key = ExpertKey::new(l, e);
            let homes = engine.placement().homes(key).to_vec();
            if homes.len() < 2 {
                continue;
            }
            replicated += 1;
            engine.transfer_handle().with_state(|st| {
                for d in 0..st.n_devices() {
                    let resident = st.devices[d].cache.is_gpu(key);
                    assert_eq!(
                        resident,
                        homes.contains(&d),
                        "layer {l} expert {e}: residency on device {d} must match its home set"
                    );
                }
            });
        }
    }
    // rf = 2 deals the top-2 ranked experts per layer to two homes each.
    assert_eq!(replicated, 2 * cfg.n_layers, "two replicated experts per layer");
    engine.shutdown();
}

#[test]
fn eviction_never_strips_replicas_below_intent() {
    // Serve real traffic with online re-placement disabled: demand loads
    // churn the caches, but victim selection must never touch a
    // replicated expert — its home set is exactly intact afterwards.
    let (cfg, store) = setup();
    let mut scfg = replicated_scfg(2, 2, TopologyKind::FullyConnected);
    scfg.replan_interval_steps = 0;
    let (server, _) = serve(&cfg, store, scfg);
    let placement = server.engine.placement().clone();
    server.engine.transfer_handle().with_state(|st| {
        let mut checked = 0usize;
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let key = ExpertKey::new(l, e);
                let homes = placement.homes(key);
                if homes.len() < 2 {
                    continue;
                }
                checked += 1;
                for &d in homes {
                    assert!(
                        st.devices[d].cache.is_gpu(key),
                        "layer {l} expert {e}: replica on device {d} was evicted"
                    );
                }
            }
        }
        assert!(checked > 0, "the fleet must have replicated experts to shield");
    });
    server.engine.shutdown();
}

#[test]
fn replicated_ring_fleet_is_deterministic_per_seed() {
    // The contended peer links (per-edge FIFO queues on the ring) and the
    // online replanner are both on the virtual timeline: same seed must
    // replay the same promotions, demotions, and clock to the nanosecond.
    let (cfg, store) = setup();
    let run = |store: Arc<WeightStore>| {
        let (server, _) = serve(&cfg, store, replicated_scfg(4, 2, TopologyKind::Ring));
        let peer_busy = server
            .engine
            .transfer_handle()
            .with_state(|st| st.peer_stats())
            .busy_seconds;
        let out = (
            server.engine.counters.get("substitutions"),
            server.engine.counters.get("cross_device_subs"),
            server.engine.counters.get("peer_hops"),
            server.engine.counters.get("replica_promotions"),
            server.engine.counters.get("replica_demotions"),
            peer_busy.to_bits(),
            server.engine.clock().now(),
        );
        server.engine.shutdown();
        out
    };
    let a = run(store.clone());
    let b = run(store);
    assert_eq!(a, b, "replicated ring fleet must replay byte-identically");
}
