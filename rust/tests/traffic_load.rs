//! Traffic subsystem integration tests: generator invariants (property
//! tests), event-queue admission end-to-end, and the golden determinism
//! contract for the load sweep (same seed + same arrival process →
//! byte-identical report under `ClockMode::Virtual`).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use buddymoe::config::{ModelConfig, ServingConfig};
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::testing::{forall, PropConfig};
use buddymoe::topology::TopologyKind;
use buddymoe::traffic::{
    cells_json, report_markdown, run_load_cell, run_sweep, run_topology_sweep,
    topology_cells_json, topology_report_markdown, ArrivalProcess, ClosedLoopProcess,
    LoadSettings, PoissonProcess, ProcessKind, PromptSource, SweepSpec, TopologySweep,
    TraceReplay,
};
use buddymoe::weights::WeightStore;

fn src(seed: u64, max_new: usize) -> PromptSource {
    PromptSource::new(&ModelConfig::test_tiny(), seed, Domain::Mixed, max_new)
}

// ---------------------------------------------------------------------
// Generator invariants (property tests)
// ---------------------------------------------------------------------

#[test]
fn prop_poisson_interarrival_mean_matches_rate() {
    forall(
        PropConfig { cases: 20, seed: 21 },
        |rng| {
            let rate = 5.0 + rng.f64() * 195.0; // 5..200 rps
            let seed = rng.next_u64();
            (rate, seed)
        },
        |&(rate, seed)| {
            let n = 400usize;
            let mut p = PoissonProcess::new(src(1, 4), rate, n, seed);
            let mut last = 0.0f64;
            let mut sum = 0.0f64;
            let mut count = 0usize;
            while let Some(a) = p.next_arrival() {
                let t = a.at.as_secs_f64();
                if t < last {
                    return Err(format!("time regressed: {t} < {last}"));
                }
                sum += t - last;
                last = t;
                count += 1;
            }
            if count != n {
                return Err(format!("emitted {count} of {n}"));
            }
            let mean = sum / n as f64;
            let want = 1.0 / rate;
            // 400 exponential samples: SE = want/20, so ±25% is ~5 sigma.
            if (mean - want).abs() > 0.25 * want {
                return Err(format!("mean inter-arrival {mean} vs expected {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_trace_replay_timestamps_monotone() {
    forall(
        PropConfig { cases: 60, seed: 22 },
        |rng| {
            // A random non-decreasing trace, expressed in milliseconds.
            let n = rng.range(1, 40);
            let mut t_ms = 0.0f64;
            let mut lines = Vec::with_capacity(n);
            for _ in 0..n {
                t_ms += rng.f64() * 10.0;
                lines.push(t_ms);
            }
            lines
        },
        |stamps| {
            let mut text = String::new();
            for t in stamps {
                text.push_str(&format!("{{\"at_ms\": {t}}}\n"));
            }
            let mut trace = TraceReplay::from_text(&text, src(2, 4))
                .map_err(|e| format!("valid trace rejected: {e}"))?;
            if trace.len() != stamps.len() {
                return Err(format!("parsed {} of {}", trace.len(), stamps.len()));
            }
            let mut prev = Duration::ZERO;
            while let Some(a) = trace.next_arrival() {
                if a.at < prev {
                    return Err(format!("replay regressed: {:?} after {:?}", a.at, prev));
                }
                if a.req.arrival_time != Some(a.at) {
                    return Err("arrival_time not stamped".into());
                }
                prev = a.at;
            }
            // Any strict regression must be rejected at parse time.
            if stamps.len() >= 2 {
                let bad = format!("{text}{{\"at_ms\": 0.0}}\n");
                if stamps.last().copied().unwrap_or(0.0) > 0.0
                    && TraceReplay::from_text(&bad, src(2, 4)).is_ok()
                {
                    return Err("time-regressing trace accepted".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_closed_loop_never_exceeds_concurrency() {
    forall(
        PropConfig { cases: 60, seed: 23 },
        |rng| {
            let concurrency = rng.range(1, 9);
            let total = rng.range(1, 41);
            let think_s = rng.f64() * 0.2;
            let seed = rng.next_u64();
            (concurrency, total, think_s, seed)
        },
        |&(concurrency, total, think_s, seed)| {
            let mut p = ClosedLoopProcess::new(src(3, 4), concurrency, think_s, total, seed);
            let mut emitted = 0usize;
            let mut outstanding = 0usize;
            while p.next_arrival().is_some() {
                emitted += 1;
                outstanding += 1;
            }
            if outstanding > concurrency {
                return Err(format!("initial wave {outstanding} > concurrency {concurrency}"));
            }
            // Complete requests one at a time; each completion may release
            // exactly one follow-up, so the bound must hold throughout.
            let mut now = Duration::ZERO;
            let mut check_rng = buddymoe::util::rng::Rng::new(seed ^ 0xc0ffee);
            while outstanding > 0 {
                now += Duration::from_secs_f64(check_rng.f64() * 0.05);
                outstanding -= 1;
                if let Some(a) = p.on_completion(now) {
                    if a.at < now {
                        return Err("follow-up scheduled in the past".into());
                    }
                    emitted += 1;
                    outstanding += 1;
                }
                if outstanding > concurrency {
                    return Err(format!("outstanding {outstanding} > concurrency {concurrency}"));
                }
            }
            if emitted != total {
                return Err(format!("emitted {emitted} of {total}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// End-to-end: event-queue admission through the server
// ---------------------------------------------------------------------

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    (cfg, store)
}

#[test]
fn example_trace_serves_every_request() {
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data/example_trace.jsonl");
    let trace =
        TraceReplay::from_path(&path, PromptSource::new(&cfg, 7, Domain::Mixed, 4)).unwrap();
    let n = trace.len();
    assert!(n >= 10, "example trace should be non-trivial");

    let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
    scfg.cache_rate = 0.5;
    let cell = run_load_cell(&cfg, store, &pc, &warm, scfg, "buddy-rho3", 0.0, Box::new(trace))
        .unwrap();
    assert_eq!(cell.requests_done as usize, n, "every trace request must complete");
    assert_eq!(cell.ttft.count(), n);
    assert_eq!(cell.tbt.count() as u64, cell.tokens_out);
    assert!(cell.wall_s >= 0.4, "trace spans 400 ms of virtual time");
    assert!(cell.queue_delay.min() >= 0.0);
}

#[test]
fn closed_loop_cell_completes_budget() {
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let process = ClosedLoopProcess::new(
        PromptSource::new(&cfg, 11, Domain::Mixed, 3),
        2,
        0.01,
        6,
        99,
    );
    let mut scfg = ServingConfig::default().preset("original").unwrap();
    scfg.cache_rate = 0.5;
    let cell =
        run_load_cell(&cfg, store, &pc, &warm, scfg, "original", 2.0, Box::new(process)).unwrap();
    assert_eq!(cell.requests_done, 6, "think-time follow-ups must all be served");
    assert!(cell.tok_s > 0.0);
}

#[test]
fn saturated_batch_builds_queue_depth_and_delay() {
    // Four simultaneous arrivals against max_batch = 2: the overflow must
    // show up as positive sampled queue depth and positive queue delay for
    // the requests that waited out earlier decode steps.
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let text = "{\"at_ms\": 0.0}\n".repeat(4);
    let trace =
        TraceReplay::from_text(&text, PromptSource::new(&cfg, 13, Domain::Mixed, 3)).unwrap();
    let mut scfg = ServingConfig::default().preset("original").unwrap();
    scfg.cache_rate = 0.5;
    scfg.max_batch = 2;
    let cell =
        run_load_cell(&cfg, store, &pc, &warm, scfg, "original", 0.0, Box::new(trace)).unwrap();
    assert_eq!(cell.requests_done, 4);
    assert!(cell.queue_depth.max() > 0.0, "overflow beyond max_batch must queue");
    assert!(cell.queue_delay.max() > 0.0, "queued requests must see admission delay");
    assert!(cell.ttft.max() >= cell.queue_delay.max(), "ttft includes the queue wait");
}

// ---------------------------------------------------------------------
// Golden determinism: byte-identical load reports per seed
// ---------------------------------------------------------------------

#[test]
fn load_sweep_report_is_byte_identical_per_seed() {
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let spec = SweepSpec {
        processes: vec![ProcessKind::Poisson, ProcessKind::Bursty],
        loads_rps: vec![8.0, 64.0],
        presets: vec!["original".into(), "buddy-rho3".into()],
        settings: LoadSettings {
            n_requests: 6,
            max_new: 4,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            trace: false,
            interactive_share: 1.0,
        },
    };
    let a = run_sweep(&cfg, store.clone(), &pc, &warm, &spec).unwrap();
    let b = run_sweep(&cfg, store, &pc, &warm, &spec).unwrap();
    assert_eq!(a.len(), 8, "2 processes x 2 loads x 2 policies");
    for c in &a {
        assert_eq!(c.requests_done, 6, "{}@{}: all requests served", c.process, c.policy);
        assert!(c.ttft.p(99.0) >= c.ttft.p(50.0));
    }
    assert_eq!(
        report_markdown(&a),
        report_markdown(&b),
        "same seed + same arrival process must reproduce the report byte-for-byte"
    );
    assert_eq!(cells_json(&a).to_string(), cells_json(&b).to_string());
}

fn topology_settings() -> LoadSettings {
    LoadSettings {
        n_requests: 6,
        max_new: 4,
        cache_rate: 0.5,
        domain: Domain::Mixed,
        seed: 42,
        trace: false,
        interactive_share: 1.0,
    }
}

#[test]
fn topology_sweep_rows_complete_and_byte_identical_per_seed() {
    // The BENCH_topology.json contract: per-fleet-shape tail-latency rows
    // that serve every request and reproduce byte-for-byte per seed.
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let spec = TopologySweep {
        device_counts: vec![1, 2],
        topologies: vec![TopologyKind::FullyConnected],
        replication_factors: vec![1],
        processes: vec![ProcessKind::Poisson],
        presets: vec!["original".into(), "buddy-rho3".into()],
        load_rps: 8.0,
        kappa: 0.25,
        settings: topology_settings(),
    };
    let a = run_topology_sweep(&cfg, store.clone(), &pc, &warm, &spec).unwrap();
    let b = run_topology_sweep(&cfg, store, &pc, &warm, &spec).unwrap();
    assert_eq!(a.len(), 4, "2 device counts x 2 policies");
    for r in &a {
        assert_eq!(
            r.cell.requests_done, 6,
            "{} devices / {}: all requests served",
            r.n_devices, r.cell.policy
        );
        assert!(r.cell.tok_s > 0.0);
        assert_eq!(r.replication_factor, 1);
        assert!(!r.probe.placement_fallback, "striped placement never falls back");
    }
    assert_eq!(topology_report_markdown(&a), topology_report_markdown(&b));
    assert_eq!(
        topology_cells_json(&a).to_string(),
        topology_cells_json(&b).to_string()
    );
}

#[test]
fn topology_sweep_replication_grid_is_deterministic_and_degenerates() {
    // Replicated cells: the grid dedups n_devices == 1 down to the first
    // topology at replication_factor 1, replicated rows run popularity
    // placement with a real rank (no fallback), and the rf = 1 rows are
    // byte-identical to a spec that never mentions replication — the
    // degenerate-case contract.
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let base = TopologySweep {
        device_counts: vec![1, 2],
        topologies: vec![TopologyKind::FullyConnected, TopologyKind::Ring],
        replication_factors: vec![1, 2],
        processes: vec![ProcessKind::Bursty],
        presets: vec!["buddy-rho3".into()],
        load_rps: 8.0,
        kappa: 0.25,
        settings: topology_settings(),
    };
    let rows = run_topology_sweep(&cfg, store.clone(), &pc, &warm, &base).unwrap();
    // n=1: 1 topo x 1 rf; n=2: 2 topo x 2 rf.
    assert_eq!(rows.len(), 5, "degenerate one-device rows must dedup");
    assert_eq!(rows.iter().filter(|r| r.n_devices == 1).count(), 1);
    for r in &rows {
        assert_eq!(r.cell.requests_done, 6);
        if r.replication_factor > 1 {
            assert_eq!(r.probe.placement, "popularity", "rank provided: no fallback");
            assert!(!r.probe.placement_fallback);
        }
    }
    // Determinism across reruns of the replicated grid.
    let again = run_topology_sweep(&cfg, store.clone(), &pc, &warm, &base).unwrap();
    assert_eq!(
        topology_cells_json(&rows).to_string(),
        topology_cells_json(&again).to_string()
    );
    // rf = 1 rows reproduce a replication-free spec byte-for-byte.
    let plain = TopologySweep { replication_factors: vec![1], ..base };
    let plain_rows = run_topology_sweep(&cfg, store, &pc, &warm, &plain).unwrap();
    let rf1: Vec<_> = rows.iter().filter(|r| r.replication_factor == 1).cloned().collect();
    assert_eq!(
        topology_cells_json(&rf1).to_string(),
        topology_cells_json(&plain_rows).to_string(),
        "replication_factor = 1 must be the byte-identical degenerate case"
    );
}
