//! End-to-end numeric validation: the rust-orchestrated engine (full
//! residency, no substitution) must reproduce the python reference model's
//! decode trace (artifacts/golden/decode.json) token-for-token and
//! logit-for-logit.
//!
//! This closes the L1→L2→L3 loop: pallas kernels → AOT HLO artifacts →
//! PJRT execution → rust routing/combine — against pure-jnp numerics.

use std::path::Path;
use std::sync::Arc;

use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::eval::{run_table, MethodSpec, TableSettings};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::util::clock::ClockMode;
use buddymoe::util::json::Json;
use buddymoe::weights::WeightStore;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("model_config.json").exists()
}

fn oracle_engine(cfg: &ModelConfig, store: Arc<WeightStore>) -> Engine {
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        record_logits: true,
        ..Default::default()
    };
    Engine::new(cfg.clone(), scfg, store, None, None, opts).expect("engine")
}

/// The virtual-clock determinism contract behind the whole eval harness: a
/// Table-2-shaped sweep (4 methods, c = 0.75) run twice with the same seed
/// must produce identical `EvalOutcome` rows — including the virtual-time
/// `wall_s` / `tok_s` measurements — and byte-identical markdown. Runs on
/// the reference backend with synthetic family weights, so it needs no
/// artifacts and finishes in well under the acceptance budget.
#[test]
fn virtual_table_sweep_is_byte_identical() {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 99));
    let settings = TableSettings {
        cache_rate: 0.75,
        n_easy: 2,
        n_hard: 2,
        max_new: 4,
        seed: 42,
        clock: ClockMode::Virtual,
    };
    let methods = vec![
        MethodSpec::new("Original (on-demand)", "original"),
        MethodSpec::new("Random", "random"),
        MethodSpec::new("BuddyMoE t=0.75 |B|=4", "buddy-tight"),
        MethodSpec::new("BuddyMoE t=0.95 |B|=16 rho=3", "buddy-rho3"),
    ];
    let (rows_a, md_a) =
        run_table(&cfg, store.clone(), &settings, &methods).expect("first sweep");
    let (rows_b, md_b) = run_table(&cfg, store, &settings, &methods).expect("second sweep");

    assert_eq!(rows_a.len(), 4);
    assert_eq!(rows_a, rows_b, "same seed must reproduce every outcome row exactly");
    assert_eq!(md_a, md_b, "markdown reports must be byte-identical");
    // Virtual time passed (the simulation modeled compute + transfers) even
    // though the sweep itself ran in milliseconds of wall time.
    for r in &rows_a {
        assert!(r.wall_s > 0.0, "virtual wall time must be positive");
        assert!(r.tok_s > 0.0, "virtual throughput must be positive");
    }
}

/// Cheaper sanity companion: two engines with the same seed generate the
/// same tokens on the reference backend (determinism below the harness).
#[test]
fn reference_engine_decode_is_deterministic() {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 7));
    let run = || {
        let mut eng = oracle_engine(&cfg, store.clone());
        let mut seq = eng.new_sequence(vec![3, 9, 17, 4], 6);
        eng.prefill(&mut seq).expect("prefill");
        for _ in 0..6 {
            let mut batch = [&mut seq];
            eng.decode_step(&mut batch).expect("decode");
        }
        eng.shutdown();
        (seq.generated.clone(), seq.logits_log.clone())
    };
    let (tok_a, log_a) = run();
    let (tok_b, log_b) = run();
    assert_eq!(tok_a, tok_b);
    assert_eq!(log_a, log_b);
}

#[test]
fn engine_matches_python_reference() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir).expect("config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    let mut eng = oracle_engine(&cfg, store);
    if eng.backend_name() != "pjrt" {
        // The golden trace was produced through the python/PJRT numerics;
        // reference-vs-PJRT parity is a separate (ROADMAP) contract.
        eprintln!("skipping: golden decode trace requires the PJRT backend");
        eng.shutdown();
        return;
    }

    let golden_text = std::fs::read_to_string(cfg.golden_path()).expect("golden file");
    let golden = Json::parse(&golden_text).expect("golden json");
    let n_steps = golden.get("n_steps").unwrap().as_usize().unwrap();

    for (ci, case) in golden.get("cases").unwrap().as_arr().unwrap().iter().enumerate() {
        let prompt: Vec<i32> = case
            .get("prompt")
            .unwrap()
            .as_usize_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let want_tokens: Vec<i32> = case
            .get("gen_tokens")
            .unwrap()
            .as_usize_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let want_logits: Vec<Vec<f32>> = case
            .get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.as_f32_vec().unwrap())
            .collect();

        let mut seq = eng.new_sequence(prompt, n_steps);
        eng.prefill(&mut seq).expect("prefill");
        for _ in 0..n_steps {
            let mut batch = [&mut seq];
            eng.decode_step(&mut batch).expect("decode");
        }
        assert_eq!(
            seq.generated, want_tokens,
            "case {ci}: generated tokens diverge from python reference"
        );
        let mut max_diff = 0f32;
        for (got, want) in seq.logits_log.iter().zip(&want_logits) {
            for (g, w) in got.iter().zip(want) {
                max_diff = max_diff.max((g - w).abs());
            }
        }
        assert!(
            max_diff < 1e-2,
            "case {ci}: logits diverge (max abs diff {max_diff})"
        );
        eprintln!("case {ci}: tokens match, max logit diff {max_diff:.2e}");
    }
    eng.shutdown();
}

#[test]
fn router_fixture_matches() {
    if !have_artifacts() {
        return;
    }
    // The golden file records layer-0 routing of the first decode step;
    // an oracle engine with profiling enabled must reproduce it.
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir).expect("config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        collect_profile: true,
        ..Default::default()
    };
    let mut eng = Engine::new(cfg.clone(), scfg, store, None, None, opts).expect("engine");
    if eng.backend_name() != "pjrt" {
        eprintln!("skipping: router golden fixture requires the PJRT backend");
        eng.shutdown();
        return;
    }

    let golden_text = std::fs::read_to_string(cfg.golden_path()).unwrap();
    let golden = Json::parse(&golden_text).unwrap();
    let case = &golden.get("cases").unwrap().as_arr().unwrap()[0];
    let prompt: Vec<i32> = case
        .get("prompt")
        .unwrap()
        .as_usize_vec()
        .unwrap()
        .into_iter()
        .map(|x| x as i32)
        .collect();
    let want_idx = case.get("router_l0_step0_idx").unwrap().as_usize_vec().unwrap();
    let want_tae = case.get("router_l0_step0_tae").unwrap().as_f64().unwrap();

    let s0 = prompt.len();
    let mut seq = eng.new_sequence(prompt, 1);
    eng.prefill(&mut seq).unwrap();
    // Reset the profile so only the decode step is recorded.
    eng.profile_out = Some(buddymoe::profilecollect::ProfileCollector::new(
        cfg.n_layers,
        cfg.n_experts,
    ));
    let mut batch = [&mut seq];
    eng.decode_step(&mut batch).unwrap();
    let pc = eng.profile_out.take().unwrap();
    // One decode token recorded at layer 0; check its selected experts.
    assert_eq!(pc.tokens_seen(0), 1, "profiled decode tokens");
    let acts = &pc.layer(0).activations;
    for &e in &want_idx {
        assert!(acts[e] > 0.0, "expert {e} (rank from python) not selected; prompt len {s0}");
    }
    // TAE from recorded weights: recompute via the trace-free route —
    // activations can't give TAE, so just sanity-bound it.
    assert!((0.0..=1.0).contains(&want_tae));
    eng.shutdown();
}
