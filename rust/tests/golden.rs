//! End-to-end numeric validation: the rust-orchestrated engine (full
//! residency, no substitution) must reproduce the python reference model's
//! decode trace (artifacts/golden/decode.json) token-for-token and
//! logit-for-logit.
//!
//! This closes the L1→L2→L3 loop: pallas kernels → AOT HLO artifacts →
//! PJRT execution → rust routing/combine — against pure-jnp numerics.

use std::path::Path;
use std::sync::Arc;

use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::util::json::Json;
use buddymoe::weights::WeightStore;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("model_config.json").exists()
}

fn oracle_engine(cfg: &ModelConfig, store: Arc<WeightStore>) -> Engine {
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        time_scale: 0.0,
        record_logits: true,
        ..Default::default()
    };
    Engine::new(cfg.clone(), scfg, store, None, None, opts).expect("engine")
}

#[test]
fn engine_matches_python_reference() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir).expect("config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    let mut eng = oracle_engine(&cfg, store);

    let golden_text = std::fs::read_to_string(cfg.golden_path()).expect("golden file");
    let golden = Json::parse(&golden_text).expect("golden json");
    let n_steps = golden.get("n_steps").unwrap().as_usize().unwrap();

    for (ci, case) in golden.get("cases").unwrap().as_arr().unwrap().iter().enumerate() {
        let prompt: Vec<i32> = case
            .get("prompt")
            .unwrap()
            .as_usize_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let want_tokens: Vec<i32> = case
            .get("gen_tokens")
            .unwrap()
            .as_usize_vec()
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let want_logits: Vec<Vec<f32>> = case
            .get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.as_f32_vec().unwrap())
            .collect();

        let mut seq = eng.new_sequence(prompt, n_steps);
        eng.prefill(&mut seq).expect("prefill");
        for _ in 0..n_steps {
            let mut batch = [&mut seq];
            eng.decode_step(&mut batch).expect("decode");
        }
        assert_eq!(
            seq.generated, want_tokens,
            "case {ci}: generated tokens diverge from python reference"
        );
        let mut max_diff = 0f32;
        for (got, want) in seq.logits_log.iter().zip(&want_logits) {
            for (g, w) in got.iter().zip(want) {
                max_diff = max_diff.max((g - w).abs());
            }
        }
        assert!(
            max_diff < 1e-2,
            "case {ci}: logits diverge (max abs diff {max_diff})"
        );
        eprintln!("case {ci}: tokens match, max logit diff {max_diff:.2e}");
    }
    eng.shutdown();
}

#[test]
fn router_fixture_matches() {
    if !have_artifacts() {
        return;
    }
    // The golden file records layer-0 routing of the first decode step;
    // an oracle engine with profiling enabled must reproduce it.
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir).expect("config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        time_scale: 0.0,
        collect_profile: true,
        ..Default::default()
    };
    let mut eng = Engine::new(cfg.clone(), scfg, store, None, None, opts).expect("engine");

    let golden_text = std::fs::read_to_string(cfg.golden_path()).unwrap();
    let golden = Json::parse(&golden_text).unwrap();
    let case = &golden.get("cases").unwrap().as_arr().unwrap()[0];
    let prompt: Vec<i32> = case
        .get("prompt")
        .unwrap()
        .as_usize_vec()
        .unwrap()
        .into_iter()
        .map(|x| x as i32)
        .collect();
    let want_idx = case.get("router_l0_step0_idx").unwrap().as_usize_vec().unwrap();
    let want_tae = case.get("router_l0_step0_tae").unwrap().as_f64().unwrap();

    let s0 = prompt.len();
    let mut seq = eng.new_sequence(prompt, 1);
    eng.prefill(&mut seq).unwrap();
    // Reset the profile so only the decode step is recorded.
    eng.profile_out = Some(buddymoe::profilecollect::ProfileCollector::new(
        cfg.n_layers,
        cfg.n_experts,
    ));
    let mut batch = [&mut seq];
    eng.decode_step(&mut batch).unwrap();
    let pc = eng.profile_out.take().unwrap();
    // One decode token recorded at layer 0; check its selected experts.
    assert_eq!(pc.tokens_seen(0), 1, "profiled decode tokens");
    let acts = &pc.layer(0).activations;
    for &e in &want_idx {
        assert!(acts[e] > 0.0, "expert {e} (rank from python) not selected; prompt len {s0}");
    }
    // TAE from recorded weights: recompute via the trace-free route —
    // activations can't give TAE, so just sanity-bound it.
    assert!((0.0..=1.0).contains(&want_tae));
    eng.shutdown();
}
