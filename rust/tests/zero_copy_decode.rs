//! PR-5 contract tests: the decode hot path reads KV caches **in place**.
//!
//! * `golden_view_decode_matches_copy_path` — the view-based
//!   `attn_decode` is bit-for-bit equal to an independent reimplementation
//!   of the seed's copy-based stage (materialize the `[bb, s, d]` caches,
//!   then run the naive math over the contiguous copy), in both kernel
//!   modes at `PALLAS_THREADS` 1 and 4.
//! * `decode_step_is_kv_zero_copy_and_allocation_bounded` — a steady-state
//!   reference-backend decode step bumps `runtime::kv_copy_bytes()` by
//!   exactly 0, and its total fresh tensor-buffer allocation is smaller
//!   than a *single layer's single cache copy* (the seed allocated
//!   `2 × L × bb × s × d` per step).
//! * `engine_decode_tokens_logits_telemetry_identical_across_threads` —
//!   the full engine view path produces identical tokens, logits, and
//!   stall telemetry at 1 and 4 threads.
//!
//! The allocation/copy counters are process-global, so every test here
//! serializes on one mutex.

use std::sync::{Arc, Mutex};

use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::runtime::kernels::naive;
use buddymoe::runtime::{
    kv_copy_bytes, materialize_kv, BackendKind, KernelMode, KvSlices, RefStages, StageRunner,
};
use buddymoe::util::clock::ClockMode;
use buddymoe::util::math::softmax;
use buddymoe::util::par;
use buddymoe::util::rng::Rng;
use buddymoe::util::tensor::{alloc_probe, Tensor};
use buddymoe::weights::WeightStore;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect()
}

/// The seed's copy-based decode attention, reimplemented independently:
/// read the *contiguous* `[bb, s, d]` cache copies exactly like the
/// pre-view engine assembled them, with the naive-kernel math in the same
/// per-element reduction order as `RefStages::attend`.
#[allow(clippy::too_many_arguments)]
fn copy_path_attn_decode(
    cfg: &ModelConfig,
    store: &WeightStore,
    layer: usize,
    bb: usize,
    x: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    pos_mask: &Tensor,
) -> [Tensor; 3] {
    let d = cfg.d_model;
    let (heads, hd) = (cfg.n_heads, cfg.head_dim);
    let s = kc.dims[1];
    let ln1 = store.tensor(&format!("L{layer}.ln1")).unwrap();
    let wq = store.tensor(&format!("L{layer}.wq")).unwrap();
    let wk = store.tensor(&format!("L{layer}.wk")).unwrap();
    let wv = store.tensor(&format!("L{layer}.wv")).unwrap();
    let wo = store.tensor(&format!("L{layer}.wo")).unwrap();

    let h = naive::rms_norm_rows(&x.data, bb, d, &ln1.data, cfg.rms_eps as f32);
    let q = naive::matmul(&h, bb, d, &wq.data, d);
    let k_new = naive::matmul(&h, bb, d, &wk.data, d);
    let v_new = naive::matmul(&h, bb, d, &wv.data, d);

    let scale = 1.0 / (hd as f32).sqrt();
    let mut o = vec![0.0f32; bb * d];
    for b in 0..bb {
        let kcb = &kc.data[b * s * d..(b + 1) * s * d];
        let vcb = &vc.data[b * s * d..(b + 1) * s * d];
        let kn = &k_new[b * d..(b + 1) * d];
        let vn = &v_new[b * d..(b + 1) * d];
        let mask = &pos_mask.data[b * s..(b + 1) * s];
        let q_row = &q[b * d..(b + 1) * d];
        let o_row = &mut o[b * d..(b + 1) * d];
        let mut scores = vec![0.0f32; s + 1];
        for head in 0..heads {
            let base = head * hd;
            let qh = &q_row[base..base + hd];
            for (t, sc) in scores.iter_mut().enumerate() {
                *sc = if t < s && mask[t] <= 0.0 {
                    f32::NEG_INFINITY
                } else {
                    let kr = if t < s {
                        &kcb[t * d + base..t * d + base + hd]
                    } else {
                        &kn[base..base + hd]
                    };
                    let mut dot = 0.0f32;
                    for (&qv, &kv) in qh.iter().zip(kr) {
                        dot += qv * kv;
                    }
                    dot * scale
                };
            }
            softmax(&mut scores);
            for j in 0..hd {
                let mut acc = 0.0f32;
                for (t, &w) in scores.iter().enumerate() {
                    if w > 0.0 {
                        let vr = if t < s { &vcb[t * d + base..] } else { &vn[base..] };
                        acc += w * vr[j];
                    }
                }
                o_row[base + j] = acc;
            }
        }
    }

    let proj = naive::matmul(&o, bb, d, &wo.data, d);
    let mut y = x.data.clone();
    for (a, p) in y.iter_mut().zip(&proj) {
        *a += p;
    }
    [
        Tensor::new(vec![bb, d], y).unwrap(),
        Tensor::new(vec![bb, d], k_new).unwrap(),
        Tensor::new(vec![bb, d], v_new).unwrap(),
    ]
}

fn first_bit_diff(a: &[f32], b: &[f32]) -> Option<usize> {
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}

#[test]
fn golden_view_decode_matches_copy_path() {
    let _g = lock();
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 123));
    let (d, s) = (cfg.d_model, cfg.max_seq);
    let bb = 4usize;
    let n_real = 3usize; // one padding lane in the bucket
    let mut rng = Rng::new(9);

    // Per-sequence caches with varying fill depths; padding lanes carry
    // zero x rows and all-invalid mask rows, like the engine builds them.
    let depths = [5usize, 17, s - 1];
    let kcs: Vec<Tensor> =
        (0..n_real).map(|_| Tensor::new(vec![s, d], randv(&mut rng, s * d)).unwrap()).collect();
    let vcs: Vec<Tensor> =
        (0..n_real).map(|_| Tensor::new(vec![s, d], randv(&mut rng, s * d)).unwrap()).collect();
    let mut x = Tensor::zeros(vec![bb, d]);
    for i in 0..n_real {
        let row = randv(&mut rng, d);
        x.row_mut(i).copy_from_slice(&row);
    }
    let mut pm = Tensor::zeros(vec![bb, s]);
    for (i, &depth) in depths.iter().enumerate() {
        pm.row_mut(i)[..depth].fill(1.0);
    }

    let kr: Vec<&Tensor> = kcs.iter().collect();
    let vr: Vec<&Tensor> = vcs.iter().collect();
    let kv = KvSlices { k: &kr, v: &vr };

    // The copy path: materialize the contiguous [bb, s, d] caches (what
    // the seed engine assembled per layer) and run the independent
    // reimplementation over them.
    let (kc_m, vc_m) = materialize_kv(&kv, bb, s, d).unwrap();
    let layer = 1usize;
    let want = copy_path_attn_decode(&cfg, &store, layer, bb, &x, &kc_m, &vc_m, &pm);

    for &threads in &[1usize, 4] {
        par::set_threads(threads);
        for mode in [KernelMode::Naive, KernelMode::Blocked] {
            let st = RefStages::with_mode(cfg.clone(), store.clone(), mode);
            let got = st.attn_decode(layer, bb, &x, &kv, &pm).unwrap();
            for (gi, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.dims, w.dims);
                if let Some(i) = first_bit_diff(&g.data, &w.data) {
                    panic!(
                        "view path diverges from copy path: output {gi}, mode {mode:?}, \
                         threads {threads}, first bit diff at {i}: {} vs {}",
                        g.data[i], w.data[i]
                    );
                }
            }
        }
    }
    par::set_threads(0);
}

/// Config sized so one layer's single KV-cache copy (bb*s*d f32) dwarfs
/// everything a view-path decode step legitimately allocates.
fn zero_copy_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::synthetic_small();
    cfg.name = "zero-copy-probe".into();
    cfg.max_seq = 128;
    cfg.token_buckets = vec![1, 2, 4, 8, 16, 32, 128];
    cfg.batch_buckets = vec![1, 2, 4];
    cfg
}

#[test]
fn decode_step_is_kv_zero_copy_and_allocation_bounded() {
    let _g = lock();
    let cfg = zero_copy_cfg();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 55));

    // Sanity: the copy counter itself works (a forced materialization
    // bumps it by exactly 2 * bb * s * d * 4 bytes).
    {
        let kc = Tensor::zeros(vec![cfg.max_seq, cfg.d_model]);
        let vc = Tensor::zeros(vec![cfg.max_seq, cfg.d_model]);
        let kr = [&kc];
        let vr = [&vc];
        let before = kv_copy_bytes();
        let _ = materialize_kv(&KvSlices { k: &kr, v: &vr }, 2, cfg.max_seq, cfg.d_model)
            .unwrap();
        assert_eq!(
            kv_copy_bytes() - before,
            (2 * 2 * cfg.max_seq * cfg.d_model * 4) as u64,
            "materialize_kv must count its copies"
        );
    }

    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        backend: BackendKind::Reference,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg.clone(), scfg, store, None, None, opts).unwrap();
    let b = 4usize;
    let steps = 6usize;
    let mut seqs: Vec<_> = (0..b)
        .map(|i| engine.new_sequence(vec![3 + i as i32, 9, 17, 4], steps + 2))
        .collect();
    for sq in seqs.iter_mut() {
        engine.prefill(sq).unwrap();
    }
    // Warm one step so pooled scratch and the arena reach steady state.
    {
        let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
        engine.decode_step(&mut refs).unwrap();
    }

    let bb = cfg.batch_bucket_for(b).unwrap();
    let one_layer_one_cache = (bb * cfg.max_seq * cfg.d_model) as u64;
    for step in 0..steps {
        let kv0 = kv_copy_bytes();
        let (_, elems0) = alloc_probe::snapshot();
        let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
        engine.decode_step(&mut refs).unwrap();
        let (_, elems1) = alloc_probe::snapshot();
        assert_eq!(
            kv_copy_bytes() - kv0,
            0,
            "reference decode step {step} must copy zero KV-cache bytes"
        );
        let allocated = elems1 - elems0;
        assert!(
            allocated < one_layer_one_cache,
            "decode step {step} allocated {allocated} f32s — more than one layer's \
             single cache copy ({one_layer_one_cache}); a KV-sized buffer is being built \
             somewhere (the seed path allocated {} per step)",
            2 * cfg.n_layers as u64 * one_layer_one_cache
        );
    }
    engine.shutdown();
}

#[test]
fn engine_decode_tokens_logits_telemetry_identical_across_threads() {
    let _g = lock();
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 77));
    let run = |threads: usize| {
        par::set_threads(threads);
        let scfg = ServingConfig {
            cache_rate: 0.5,
            miss_policy: MissPolicy::OnDemand,
            prefetch: PrefetchKind::TopFreq,
            ..Default::default()
        };
        let opts = EngineOptions {
            clock: ClockMode::Virtual,
            record_logits: true,
            backend: BackendKind::Reference,
            ..Default::default()
        };
        let mut eng = Engine::new(cfg.clone(), scfg, store.clone(), None, None, opts).unwrap();
        let mut a = eng.new_sequence(vec![3, 9, 17, 4], 6);
        let mut b = eng.new_sequence(vec![5, 2, 8], 6);
        eng.prefill(&mut a).unwrap();
        eng.prefill(&mut b).unwrap();
        let mut stalls = Vec::new();
        for _ in 0..6 {
            let mut batch = [&mut a, &mut b];
            let tel = eng.decode_step(&mut batch).unwrap();
            stalls.push(tel.stall_seconds.to_bits());
        }
        eng.shutdown();
        par::set_threads(0);
        (
            a.generated.clone(),
            b.generated.clone(),
            a.logits_log.clone(),
            b.logits_log.clone(),
            stalls,
        )
    };
    let r1 = run(1);
    let r4 = run(4);
    assert_eq!(r1.0, r4.0, "tokens (seq a) must not depend on thread count");
    assert_eq!(r1.1, r4.1, "tokens (seq b) must not depend on thread count");
    assert_eq!(r1.2, r4.2, "logits (seq a) must be bitwise identical");
    assert_eq!(r1.3, r4.3, "logits (seq b) must be bitwise identical");
    assert_eq!(r1.4, r4.4, "stall telemetry must be identical");
}
