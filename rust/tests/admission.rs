//! Admission-control integration tests: the disabled-config degenerate
//! case (byte-identical sweeps across thread counts), per-seed
//! byte-identical shed decisions, the bounded staging queue under
//! saturation, and fault + overload composition (waterfall and shed
//! counters must not double-count).

use std::sync::Arc;

use buddymoe::config::{AdmissionControl, ModelConfig, ServingConfig};
use buddymoe::eval::{engine_with_config, profile_model, warm_rank_from_profile, Domain};
use buddymoe::fault::FaultPlan;
use buddymoe::model::EngineOptions;
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::server::Server;
use buddymoe::topology::TopologyKind;
use buddymoe::traffic::{
    cells_json, overload_cells_json, report_markdown, run_overload_sweep, run_sweep,
    AdmissionMode, ArrivalProcess, BurstyProcess, LoadSettings, OverloadSweep, ProcessKind,
    PromptSource, SweepSpec,
};
use buddymoe::util::clock::ClockMode;
use buddymoe::util::par;
use buddymoe::weights::WeightStore;

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    (cfg, store)
}

fn profiled(
    cfg: &ModelConfig,
    store: &Arc<WeightStore>,
) -> (ProfileCollector, Vec<Vec<usize>>) {
    let pc = profile_model(cfg, store.clone(), 8, 7777).expect("profiling the tiny model");
    let warm = warm_rank_from_profile(&pc);
    (pc, warm)
}

/// Serve one admission-enabled cell end to end on a fresh engine and
/// return the server (metrics still attached) for invariant checks.
fn run_gated_server(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    pc: &ProfileCollector,
    warm: &[Vec<usize>],
    mut scfg: ServingConfig,
    n_requests: usize,
    burst_rps: f64,
) -> Server {
    scfg.cache_rate = 0.5;
    let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
    let engine = engine_with_config(cfg, store, pc, warm, scfg, opts)
        .expect("engine builds for the gated cell");
    let mut server = Server::new(engine);
    let src = PromptSource::new(cfg, 42, Domain::Mixed, 4).with_interactive_share(0.5, 0x510);
    let mut process: Box<dyn ArrivalProcess> =
        Box::new(BurstyProcess::new(src, burst_rps, 0.0, 0.25, 0.25, n_requests, 97));
    server.batcher.stage_process(process.as_mut());
    server.batcher.close();
    server.run().expect("gated run drains");
    server
}

// ---------------------------------------------------------------------
// Disabled config: the degenerate case stays the seed loop
// ---------------------------------------------------------------------

#[test]
fn disabled_admission_sweep_is_byte_identical_across_thread_counts() {
    // The default (admission-disabled) config must keep the existing
    // sweeps byte-identical regardless of PALLAS_THREADS — the scheduler
    // rewiring may not perturb the golden path. (The CI driver further
    // diffs these against the pre-PR goldens.)
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let spec = SweepSpec {
        processes: vec![ProcessKind::Bursty],
        loads_rps: vec![8.0, 64.0],
        presets: vec!["original".into(), "buddy-rho3".into()],
        settings: LoadSettings {
            n_requests: 6,
            max_new: 4,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            trace: false,
            interactive_share: 1.0,
        },
    };
    par::set_threads(1);
    let a = run_sweep(&cfg, store.clone(), &pc, &warm, &spec).expect("1-thread sweep");
    par::set_threads(4);
    let b = run_sweep(&cfg, store, &pc, &warm, &spec).expect("4-thread sweep");
    par::set_threads(0);
    assert_eq!(
        report_markdown(&a),
        report_markdown(&b),
        "disabled-admission report must not depend on PALLAS_THREADS"
    );
    assert_eq!(cells_json(&a).to_string(), cells_json(&b).to_string());
}

#[test]
fn disabled_admission_report_has_no_overload_lines() {
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let scfg = ServingConfig::default().preset("original").expect("preset");
    let server = run_gated_server(&cfg, store, &pc, &warm, scfg, 6, 32.0);
    assert_eq!(server.metrics.shed_requests, 0);
    assert_eq!(server.metrics.brownout_transitions, 0);
    assert!(server.metrics.shed_log.is_empty());
    let report = server.metrics.report();
    assert!(
        !report.contains("shed:") && !report.contains("brownout:"),
        "default report must stay byte-identical to the pre-admission format:\n{report}"
    );
}

// ---------------------------------------------------------------------
// Shed determinism: byte-identical decisions per seed
// ---------------------------------------------------------------------

#[test]
fn shed_decisions_are_per_seed_byte_identical() {
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let gated_scfg = || {
        let mut scfg = ServingConfig::default().preset("buddy-rho3").expect("preset");
        // A tiny cap against a hard burst forces both shed reasons.
        scfg.admission = AdmissionControl::overload_protect(0.05, 0.5, 4);
        scfg
    };
    let run = || {
        run_gated_server(&cfg, store.clone(), &pc, &warm, gated_scfg(), 24, 400.0)
    };
    let a = run();
    let b = run();
    assert!(a.metrics.shed_requests > 0, "the burst must overflow the cap");
    assert_eq!(
        format!("{:?}", a.metrics.shed_log),
        format!("{:?}", b.metrics.shed_log),
        "shed decisions (ids, classes, reasons, instants) must replay byte-identically"
    );
    assert_eq!(a.metrics.brownout_transitions, b.metrics.brownout_transitions);
    assert_eq!(
        a.metrics.brownout_dwell_s.to_bits(),
        b.metrics.brownout_dwell_s.to_bits(),
        "brownout dwell must be bit-identical per seed"
    );
    assert_eq!(a.metrics.report(), b.metrics.report());
}

#[test]
fn overload_sweep_json_is_byte_identical_per_seed() {
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let spec = OverloadSweep {
        loads_rps: vec![8.0, 96.0],
        presets: vec!["buddy-rho3".into()],
        admissions: vec![AdmissionMode::Fifo, AdmissionMode::Slo],
        process: ProcessKind::Bursty,
        interactive_ttft_slo_s: 0.05,
        batch_ttft_slo_s: 0.5,
        queue_cap: 4,
        settings: LoadSettings {
            n_requests: 8,
            max_new: 4,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            trace: false,
            interactive_share: 0.5,
        },
    };
    let a = run_overload_sweep(&cfg, store.clone(), &pc, &warm, &spec).expect("sweep a");
    let b = run_overload_sweep(&cfg, store, &pc, &warm, &spec).expect("sweep b");
    assert_eq!(a.len(), 4, "2 loads x 1 preset x 2 admission modes");
    assert_eq!(
        overload_cells_json(&a).to_string(),
        overload_cells_json(&b).to_string(),
        "overload rows (shed rates, brownout dwell, tails) must replay byte-identically"
    );
    // FIFO rows shed nothing by construction.
    for r in a.iter().filter(|r| r.admission == "fifo") {
        assert_eq!(r.probe.shed_requests, 0, "no gate, no sheds");
        assert_eq!(r.probe.brownout_transitions, 0);
    }
}

// ---------------------------------------------------------------------
// Bounded staging queue under saturation
// ---------------------------------------------------------------------

#[test]
fn queue_cap_bounds_staging_depth_under_saturation() {
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let cap = 4usize;
    let n = 32usize;
    let mut scfg = ServingConfig::default().preset("buddy-rho3").expect("preset");
    // Cap only (huge budgets, no deadline shedding): isolates the
    // backpressure bound.
    let mut ac = AdmissionControl::overload_protect(10.0, 100.0, cap);
    ac.shed_unmeetable = false;
    ac.brownout_enter_ratio = 0.0;
    scfg.admission = ac;
    let server = run_gated_server(&cfg, store, &pc, &warm, scfg, n, 800.0);
    let m = &server.metrics;
    let poll = server.batcher.poll_stats();
    assert!(
        poll.max_depth <= cap,
        "staging depth {} exceeded the hard cap {}",
        poll.max_depth,
        cap
    );
    assert!(poll.polls > 0, "the depth gauge must have sampled");
    assert!(m.shed_requests > 0, "an 800-rps burst against cap 4 must shed");
    assert_eq!(m.shed_requests, m.shed_queue_full, "cap-only config sheds only QueueFull");
    assert_eq!(
        m.shed_requests + m.requests_done,
        n as u64,
        "every request must be exactly one of shed or done"
    );
}

// ---------------------------------------------------------------------
// Faults + overload compose without double-counting
// ---------------------------------------------------------------------

#[test]
fn device_down_during_burst_composes_with_shedding() {
    let (cfg, store) = setup();
    let (pc, warm) = profiled(&cfg, &store);
    let n = 24usize;
    let mut scfg = ServingConfig::default().preset("buddy-rho3").expect("preset");
    scfg.n_devices = 4;
    scfg.topology = TopologyKind::Ring;
    scfg.fault_plan =
        FaultPlan::scenario("device-down").expect("device-down is a built-in scenario");
    scfg.admission = AdmissionControl::overload_protect(0.05, 0.5, 4);
    let mut server = {
        scfg.cache_rate = 0.5;
        let opts = EngineOptions { clock: ClockMode::Virtual, ..Default::default() };
        let engine = engine_with_config(&cfg, store, &pc, &warm, scfg, opts)
            .expect("engine builds with faults + admission");
        Server::new(engine)
    };
    let src = PromptSource::new(&cfg, 42, Domain::Mixed, 4).with_interactive_share(0.5, 0x510);
    // Low idle rate keeps arrivals flowing across the 1–3 s fault window
    // while the bursts still overflow the cap.
    let mut process: Box<dyn ArrivalProcess> =
        Box::new(BurstyProcess::new(src, 400.0, 2.0, 0.25, 0.5, n, 97));
    server.batcher.stage_process(process.as_mut());
    server.batcher.close();
    let done = server.run().expect("faulted gated run drains");

    let m = &server.metrics;
    assert!(m.shed_requests > 0, "the burst must shed against cap 4");
    assert_eq!(
        m.shed_requests + m.requests_done,
        n as u64,
        "shed and done must partition the offered requests"
    );
    assert_eq!(done.len() as u64, m.requests_done);
    // No double-counting across the two protection layers: a shed request
    // was never admitted, so it can be neither done nor degraded.
    let done_ids: std::collections::BTreeSet<u64> = done.iter().map(|r| r.id).collect();
    for shed in &m.shed_log {
        assert!(
            !done_ids.contains(&shed.id),
            "request {} is both shed and done",
            shed.id
        );
    }
    assert!(
        m.degraded_requests <= m.requests_done,
        "degraded annotations only apply to completed requests"
    );
    assert_eq!(
        m.shed_interactive + m.shed_batch,
        m.shed_requests,
        "class counters must partition the sheds"
    );
    assert_eq!(
        m.shed_queue_full + m.shed_deadline,
        m.shed_requests,
        "reason counters must partition the sheds"
    );
}
