//! End-to-end serving tests: continuous batching, policy behaviour under
//! memory pressure, teacher forcing, failure modes. (Virtual clock:
//! instant, deterministic simulated transfers — these tests check
//! correctness and accounting, not latency.)
//!
//! When the AOT artifacts are present the tests run over them (PJRT
//! backend, `pjrt` feature); otherwise they fall back to a synthetic
//! family-structured `WeightStore` on the pure-Rust reference backend, so
//! the full pipeline is exercised either way instead of silently skipping.

use std::path::Path;
use std::sync::Arc;

use buddymoe::buddy::BuddyProfile;
use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::eval::{forced_agreement, profile_model, warm_rank_from_profile, Domain, WorkloadGen};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::server::{InferenceRequest, Server};
use buddymoe::util::clock::ClockMode;
use buddymoe::weights::WeightStore;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let dir = artifacts_dir();
    if dir.join("model_config.json").exists() {
        let cfg = ModelConfig::load(&dir).unwrap();
        let store = Arc::new(WeightStore::load(&cfg).unwrap());
        (cfg, store)
    } else {
        let cfg = ModelConfig::synthetic_small();
        let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
        (cfg, store)
    }
}

fn engine_with(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
    policy: MissPolicy,
    cache_rate: f64,
) -> Engine {
    let pc = profile_model(cfg, store.clone(), 8, 555).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let mut scfg = ServingConfig {
        cache_rate,
        miss_policy: policy,
        prefetch: PrefetchKind::TopFreq,
        ..Default::default()
    };
    scfg.tae_tau = 0.5;
    let buddies =
        BuddyProfile::build(&pc, &vec![scfg.cft_alpha; cfg.n_layers], scfg.k_max, 1e-3, true)
            .unwrap();
    Engine::new(
        cfg.clone(),
        scfg,
        store,
        Some(buddies),
        Some(warm),
        EngineOptions { clock: ClockMode::Virtual, record_logits: true, ..Default::default() },
    )
    .unwrap()
}

#[test]
fn continuous_batching_completes_all_requests() {
    let (cfg, store) = setup();
    let engine = engine_with(&cfg, store, MissPolicy::Buddy, 0.5);
    let mut server = Server::new(engine);
    let mut gen = WorkloadGen::new(&cfg, 9);
    gen.max_new = 6;
    // More requests than max_batch: forces multiple admission waves.
    let n = server.engine.scfg.max_batch * 2 + 3;
    let reqs = gen.requests(Domain::Mixed, n, 0);
    let responses = server.run_offline(reqs).unwrap();
    assert_eq!(responses.len(), n);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    for r in &responses {
        assert_eq!(r.tokens.len(), 6);
        assert_eq!(r.predictions.len(), 7); // prefill + 6 steps
        assert_eq!(r.logits.len(), 7);
        assert!(r.ttft <= r.total);
        // first_token_time is absolute (arrival + ttft on a clock that
        // starts at zero), so it can never undercut the relative ttft.
        assert!(r.first_token_time >= r.ttft);
    }
    assert_eq!(server.metrics.requests_done as usize, n);
    server.engine.shutdown();
}

#[test]
fn on_demand_is_lossless_under_pressure() {
    let (cfg, store) = setup();
    // Oracle: full residency.
    let oracle_engine = engine_with(&cfg, store.clone(), MissPolicy::OnDemand, 1.0);
    let mut oracle_server = Server::new(oracle_engine);
    let mut gen = WorkloadGen::new(&cfg, 10);
    gen.max_new = 5;
    let reqs = gen.requests(Domain::Mixed, 4, 0);
    let mut oracle = oracle_server.run_offline(reqs.clone()).unwrap();
    oracle.sort_by_key(|r| r.id);
    oracle_server.engine.shutdown();

    // Served: c=0.375, on-demand (lossless, teacher-forced to oracle).
    let engine = engine_with(&cfg, store, MissPolicy::OnDemand, 0.375);
    let mut server = Server::new(engine);
    let forced: Vec<InferenceRequest> = reqs
        .into_iter()
        .map(|r| {
            let o = oracle.iter().find(|x| x.id == r.id).unwrap();
            r.forced(o.predictions.clone())
        })
        .collect();
    let mut served = server.run_offline(forced).unwrap();
    served.sort_by_key(|r| r.id);
    let o_refs: Vec<_> = oracle.iter().collect();
    let s_refs: Vec<_> = served.iter().collect();
    let acc = forced_agreement(&o_refs, &s_refs);
    assert!(
        acc > 0.999,
        "on-demand must be lossless (got agreement {acc})"
    );
    assert!(server.engine.counters.get("fetches") > 0, "pressure must cause fetches");
    assert_eq!(server.engine.counters.get("substitutions"), 0);
    server.engine.shutdown();
}

#[test]
fn buddy_policy_substitutes_and_stays_usable() {
    let (cfg, store) = setup();
    let oracle_engine = engine_with(&cfg, store.clone(), MissPolicy::OnDemand, 1.0);
    let mut oracle_server = Server::new(oracle_engine);
    let mut gen = WorkloadGen::new(&cfg, 11);
    gen.max_new = 5;
    let reqs = gen.requests(Domain::Mixed, 4, 0);
    let mut oracle = oracle_server.run_offline(reqs.clone()).unwrap();
    oracle.sort_by_key(|r| r.id);
    oracle_server.engine.shutdown();

    let engine = engine_with(&cfg, store, MissPolicy::Buddy, 0.375);
    let mut server = Server::new(engine);
    let forced: Vec<InferenceRequest> = reqs
        .into_iter()
        .map(|r| {
            let o = oracle.iter().find(|x| x.id == r.id).unwrap();
            r.forced(o.predictions.clone())
        })
        .collect();
    let mut served = server.run_offline(forced).unwrap();
    served.sort_by_key(|r| r.id);
    let o_refs: Vec<_> = oracle.iter().collect();
    let s_refs: Vec<_> = served.iter().collect();
    let acc = forced_agreement(&o_refs, &s_refs);
    let subs = server.engine.counters.get("substitutions");
    assert!(subs > 0, "buddy policy must substitute under c=0.375");
    assert!(
        acc > 0.5,
        "substitution must keep the model usable (got {acc})"
    );
    server.engine.shutdown();
}

#[test]
fn drop_policy_runs_and_degrades_gracefully() {
    let (cfg, store) = setup();
    let engine = engine_with(&cfg, store, MissPolicy::Drop, 0.375);
    let mut server = Server::new(engine);
    let mut gen = WorkloadGen::new(&cfg, 12);
    gen.max_new = 4;
    let reqs = gen.requests(Domain::Mixed, 3, 0);
    let responses = server.run_offline(reqs).unwrap();
    assert_eq!(responses.len(), 3);
    assert!(server.engine.counters.get("drops") > 0);
    assert_eq!(server.engine.counters.get("fetches"), 0, "drop never fetches");
    server.engine.shutdown();
}

#[test]
fn teacher_forcing_follows_oracle_tokens() {
    let (cfg, store) = setup();
    let engine = engine_with(&cfg, store, MissPolicy::OnDemand, 1.0);
    let mut server = Server::new(engine);
    let forced_tokens: Vec<i32> = vec![5, 6, 7, 8, 9];
    let req = InferenceRequest::new(0, vec![3, 4, 5], 4).forced(forced_tokens.clone());
    let responses = server.run_offline(vec![req]).unwrap();
    // generated = fed tokens = forced stream positions 0..4.
    assert_eq!(responses[0].tokens, vec![5, 6, 7, 8]);
    // predictions are the model's own argmaxes - present and full length.
    assert_eq!(responses[0].predictions.len(), 5);
    server.engine.shutdown();
}

#[test]
fn cache_rate_one_never_fetches() {
    let (cfg, store) = setup();
    let engine = engine_with(&cfg, store, MissPolicy::Buddy, 1.0);
    let mut server = Server::new(engine);
    let mut gen = WorkloadGen::new(&cfg, 13);
    gen.max_new = 4;
    let reqs = gen.requests(Domain::Mixed, 2, 0);
    server.run_offline(reqs).unwrap();
    assert_eq!(server.engine.counters.get("fetches"), 0);
    assert_eq!(server.engine.counters.get("substitutions"), 0);
    assert_eq!(server.engine.counters.get("slots_miss"), 0);
    server.engine.shutdown();
}
