//! Regression tests for NaN-poisoned statistics: every ranking sort used
//! to call `partial_cmp(..).unwrap()` (or fall back to `Equal`, breaking
//! sort transitivity) and would panic — or misbehave — on a NaN
//! activation/bias entry. With `total_cmp` a NaN ranks deterministically
//! (positive NaN above every number in the descending sorts) and non-NaN
//! orderings are unchanged, so the golden sweeps stay byte-identical.

use buddymoe::buddy::BuddyProfile;
use buddymoe::eval::warm_rank_from_profile;
use buddymoe::prefetch::{PredictContext, Predictor, TopFreq};
use buddymoe::profilecollect::ProfileCollector;
use buddymoe::util::math::{percentile, top_k};

/// A collector whose first recorded token is weighted NaN (via the
/// warm-up discount), poisoning the activation counts and co-activation
/// matrices of experts 0 and 1. Experts 2 and 3 stay finite.
fn nan_collector() -> ProfileCollector {
    let mut pc = ProfileCollector::new(1, 4).with_warmup(1, f64::NAN);
    pc.record(0, &[0, 1], &[0.5, 0.5]).unwrap(); // NaN-weighted token
    pc.record(0, &[2, 3], &[0.6, 0.4]).unwrap();
    pc.record(0, &[2, 3], &[0.6, 0.4]).unwrap();
    pc
}

#[test]
fn warm_rank_survives_nan_activations() {
    // Panicked before the fix: `partial_cmp(NaN).unwrap()` in
    // warm_rank_from_profile.
    let rank = warm_rank_from_profile(&nan_collector());
    assert_eq!(rank[0].len(), 4);
    // Deterministic total order: +NaN sorts above every number in the
    // descending total_cmp order, ties broken by expert index; the finite
    // pair (2, 3) keeps its count-then-index order.
    assert_eq!(rank[0], vec![0, 1, 2, 3]);
}

#[test]
fn topfreq_survives_nan_activations() {
    // Same sort inside the TopFreq predictor — also panicked before.
    let mut tf = TopFreq::from_profile(&nan_collector());
    let ctx = PredictContext { hidden: None, actual: None };
    let pred = tf.predict(0, 3, &ctx);
    assert_eq!(pred.len(), 3);
    assert!(pred.iter().all(|&e| e < 4));
}

#[test]
fn percentile_survives_nan_samples() {
    // The stats path's last partial_cmp(..).unwrap_or(Equal) sort: a NaN
    // latency sample defeats the sorted fast-path check (NaN comparisons
    // are false), so the sort always ran with a non-transitive comparator
    // — order (and thus every reported percentile) was
    // implementation-defined. total_cmp sorts NaN deterministically above
    // +inf, so the finite percentiles and the NaN tail are stable.
    let xs = [3.0f32, f32::NAN, 1.0, 2.0, f32::NAN, 0.5];
    let a = percentile(&xs, 50.0);
    let b = percentile(&xs, 50.0);
    assert_eq!(a.to_bits(), b.to_bits(), "NaN input must sort deterministically");
    // The finite prefix is properly ordered: low percentiles are real.
    assert_eq!(percentile(&xs, 0.0), 0.5);
    assert_eq!(percentile(&xs, 40.0), 2.0);
    // NaN ranks above every number, so the max lands on the NaN tail.
    assert!(percentile(&xs, 100.0).is_nan());
    // Finite inputs are untouched by the comparator change.
    let ys = [4.0f32, 1.0, 3.0, 2.0];
    assert_eq!(percentile(&ys, 100.0), 4.0);
    assert_eq!(percentile(&ys, 50.0), 2.5);
}

#[test]
fn top_k_survives_nan_gate_probs() {
    // The router's top_k comparator was the last partial_cmp(..)
    // .unwrap_or(Equal) ranking sort (found by pallas-lint's float-sort
    // rule): NaN-as-Equal is non-transitive, so a NaN gate probability
    // made the selected expert set comparator-dependent. total_cmp ranks
    // +NaN above every number, deterministically.
    let probs = [0.2f32, f32::NAN, 0.5];
    let (idx, w) = top_k(&probs, 2);
    assert_eq!(idx, vec![1, 2], "NaN ranks first, then the largest finite prob");
    // The NaN poisons the renormalization sum, so weights fall back to
    // the uniform 1/k split instead of propagating NaN everywhere.
    assert_eq!(w, vec![0.5, 0.5]);
    let (idx2, _) = top_k(&probs, 2);
    assert_eq!(idx, idx2, "NaN ranking must be deterministic");
    // Finite inputs keep the exact pre-fix order (prob desc, index asc).
    let (fin, _) = top_k(&[0.1f32, 0.4, 0.4, 0.2], 3);
    assert_eq!(fin, vec![1, 2, 3]);
}

#[test]
fn buddy_lists_survive_nan_co_activation() {
    // The buddy-list sort used `partial_cmp(..).unwrap_or(Equal)`: no
    // panic, but NaN-as-Equal is non-transitive and the resulting order
    // was comparator-dependent. total_cmp gives a deterministic total
    // order; the lists must still build and stay non-empty.
    let pc = nan_collector();
    let a = BuddyProfile::build(&pc, &[0.9], 4, 1e-3, true).unwrap();
    let b = BuddyProfile::build(&pc, &[0.9], 4, 1e-3, true).unwrap();
    for i in 0..4 {
        assert!(!a.list(0, i).is_empty(), "pivot {i} list empty");
        assert_eq!(a.list(0, i), b.list(0, i), "pivot {i} order not deterministic");
    }
}
