//! Backend parity (ROADMAP open item): when the AOT artifacts are present
//! *and* the `pjrt` feature is compiled in, the pure-Rust reference
//! interpreter must agree with the PJRT executor on the golden decode
//! trace — same tokens, near-identical logits. This closes the loop on
//! the reference interpreter's numerics: `tests/golden.rs` pins PJRT to
//! the python reference, and this test pins the rust interpreter to PJRT.
//!
//! Skips cleanly (with a note) when artifacts are absent or the feature
//! is off, so the default artifact-free build stays green.

use std::path::Path;
use std::sync::Arc;

use buddymoe::config::{MissPolicy, ModelConfig, PrefetchKind, ServingConfig};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::runtime::BackendKind;
use buddymoe::util::clock::ClockMode;
use buddymoe::util::json::Json;
use buddymoe::weights::WeightStore;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn oracle_engine(cfg: &ModelConfig, store: Arc<WeightStore>, backend: BackendKind) -> Engine {
    let scfg = ServingConfig {
        cache_rate: 1.0,
        miss_policy: MissPolicy::OnDemand,
        prefetch: PrefetchKind::None,
        ..Default::default()
    };
    let opts = EngineOptions {
        clock: ClockMode::Virtual,
        record_logits: true,
        backend,
        ..Default::default()
    };
    Engine::new(cfg.clone(), scfg, store, None, None, opts).expect("engine")
}

#[test]
fn reference_and_pjrt_backends_agree_on_golden_decode() {
    if !artifacts_dir().join("model_config.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: pjrt feature not compiled (cargo test --features pjrt)");
        return;
    }
    let cfg = ModelConfig::load(&artifacts_dir()).expect("config");
    let store = Arc::new(WeightStore::load(&cfg).expect("weights"));
    let golden_text = std::fs::read_to_string(cfg.golden_path()).expect("golden file");
    let golden = Json::parse(&golden_text).expect("golden json");
    let n_steps = golden.get("n_steps").unwrap().as_usize().unwrap();
    let cases = golden.get("cases").unwrap().as_arr().unwrap();

    // Decode every golden prompt through one backend.
    let run = |backend: BackendKind, name: &str| {
        let mut eng = oracle_engine(&cfg, store.clone(), backend);
        assert_eq!(eng.backend_name(), name, "requested backend must be in use");
        let mut out = Vec::new();
        for case in cases {
            let prompt: Vec<i32> = case
                .get("prompt")
                .unwrap()
                .as_usize_vec()
                .unwrap()
                .into_iter()
                .map(|x| x as i32)
                .collect();
            let mut seq = eng.new_sequence(prompt, n_steps);
            eng.prefill(&mut seq).expect("prefill");
            for _ in 0..n_steps {
                let mut batch = [&mut seq];
                eng.decode_step(&mut batch).expect("decode");
            }
            out.push((seq.generated.clone(), seq.logits_log.clone()));
        }
        eng.shutdown();
        out
    };

    let reference = run(BackendKind::Reference, "reference");
    let pjrt = run(BackendKind::Pjrt, "pjrt");

    assert_eq!(reference.len(), pjrt.len());
    for (ci, ((r_tok, r_log), (p_tok, p_log))) in reference.iter().zip(&pjrt).enumerate() {
        assert_eq!(
            r_tok, p_tok,
            "case {ci}: generated tokens diverge between reference and PJRT backends"
        );
        let mut max_diff = 0f32;
        for (a, b) in r_log.iter().zip(p_log) {
            assert_eq!(a.len(), b.len(), "case {ci}: logit widths differ");
            for (x, y) in a.iter().zip(b) {
                max_diff = max_diff.max((x - y).abs());
            }
        }
        assert!(
            max_diff < 1e-2,
            "case {ci}: logits diverge between backends (max abs diff {max_diff})"
        );
        eprintln!("case {ci}: backends agree, max logit diff {max_diff:.2e}");
    }
}
