//! Tracing & stall-attribution integration tests: the observability
//! invariants from ROADMAP.md.
//!
//! * Off is free *and* invisible: running a cell with the trace sink off
//!   produces byte-identical reports to the pre-trace goldens (covered by
//!   the existing golden tests staying green), and running the *same*
//!   cell traced changes none of the measured metrics.
//! * On is deterministic: the exported trace (Chrome JSON and JSONL) is
//!   byte-identical per seed across kernel thread counts — instrumentation
//!   lives only in single-threaded orchestration code.
//! * Attribution is exact: for every finished request, the stall buckets
//!   sum bit-for-bit to the measured end-to-end latency, including
//!   degraded and faulted requests.
//! * The Chrome export is schema-valid: parseable JSON with the expected
//!   process/track metadata and span names, so Perfetto loads it.

use std::sync::{Arc, Mutex};

use buddymoe::config::{ModelConfig, ServingConfig};
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::fault::FaultPlan;
use buddymoe::topology::TopologyKind;
use buddymoe::traffic::{
    report_markdown, run_fault_cell_traced, run_load_cell, run_load_cell_traced, LoadSettings,
    ProcessKind, TraceOutput,
};
use buddymoe::trace::RequestAttribution;
use buddymoe::util::json::Json;
use buddymoe::util::par;
use buddymoe::weights::WeightStore;

/// `par::set_threads` is a process-global override and the test harness
/// runs tests concurrently; serialize the test that drives it.
static PAR_LOCK: Mutex<()> = Mutex::new(());

fn setup() -> (ModelConfig, Arc<WeightStore>) {
    let cfg = ModelConfig::synthetic_small();
    let store = Arc::new(WeightStore::synthetic_families(&cfg, 2024));
    (cfg, store)
}

fn settings() -> LoadSettings {
    LoadSettings {
        n_requests: 6,
        max_new: 4,
        cache_rate: 0.5,
        domain: Domain::Mixed,
        seed: 42,
        trace: true,
        interactive_share: 1.0,
    }
}

/// One traced load cell on the buddy preset (bursty arrivals, so queueing
/// and prefetch misses both occur).
fn run_traced(
    cfg: &ModelConfig,
    store: Arc<WeightStore>,
) -> (buddymoe::traffic::LoadCell, TraceOutput) {
    let pc = profile_model(cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let st = settings();
    let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
    scfg.cache_rate = st.cache_rate;
    scfg.seed = st.seed;
    let process = ProcessKind::Bursty.build(cfg, &st, 16.0);
    run_load_cell_traced(cfg, store, &pc, &warm, scfg, "buddy-rho3", 16.0, process).unwrap()
}

// ---------------------------------------------------------------------
// Determinism: per-seed byte-identical traces across thread counts
// ---------------------------------------------------------------------

#[test]
fn trace_is_byte_identical_across_thread_counts() {
    let _guard = PAR_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (cfg, store) = setup();
    par::set_threads(1);
    let (_, t1) = run_traced(&cfg, store.clone());
    par::set_threads(4);
    let (_, t4) = run_traced(&cfg, store);
    par::set_threads(0);
    assert!(!t1.chrome_json.is_empty() && !t1.jsonl.is_empty());
    assert_eq!(
        t1.chrome_json, t4.chrome_json,
        "Chrome trace must not depend on the kernel thread count"
    );
    assert_eq!(t1.jsonl, t4.jsonl, "JSONL trace must not depend on the kernel thread count");
    assert_eq!(t1.attributions.len(), t4.attributions.len());
    for (a, b) in t1.attributions.iter().zip(&t4.attributions) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}

#[test]
fn trace_is_byte_identical_per_seed() {
    let (cfg, store) = setup();
    let (_, a) = run_traced(&cfg, store.clone());
    let (_, b) = run_traced(&cfg, store);
    assert_eq!(a.chrome_json, b.chrome_json, "same seed must reproduce the trace byte-for-byte");
    assert_eq!(a.jsonl, b.jsonl);
}

// ---------------------------------------------------------------------
// Zero-cost-off: tracing changes no measured metric
// ---------------------------------------------------------------------

#[test]
fn tracing_does_not_change_metrics() {
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    let st = settings();
    let mk_scfg = || {
        let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
        scfg.cache_rate = st.cache_rate;
        scfg.seed = st.seed;
        scfg
    };
    let off = run_load_cell(
        &cfg,
        store.clone(),
        &pc,
        &warm,
        mk_scfg(),
        "buddy-rho3",
        16.0,
        ProcessKind::Bursty.build(&cfg, &st, 16.0),
    )
    .unwrap();
    let (on, trace) = run_load_cell_traced(
        &cfg,
        store,
        &pc,
        &warm,
        mk_scfg(),
        "buddy-rho3",
        16.0,
        ProcessKind::Bursty.build(&cfg, &st, 16.0),
    )
    .unwrap();
    // The full metric row (every percentile) must be byte-identical; the
    // only difference tracing makes is the extra p99_attr payload.
    assert_eq!(
        report_markdown(std::slice::from_ref(&off)),
        report_markdown(std::slice::from_ref(&on)),
        "tracing must not perturb any measured metric"
    );
    assert!(off.p99_attr.is_none(), "untraced cells carry no attribution");
    assert!(on.p99_attr.is_some(), "traced cells carry the p99 attribution");
    assert_eq!(trace.attributions.len(), on.requests_done as usize);
}

// ---------------------------------------------------------------------
// Attribution exactness (property over faulted + degraded requests)
// ---------------------------------------------------------------------

fn assert_exact(a: &RequestAttribution, ctx: &str) {
    // Durations are non-negative by construction; exactness is the claim:
    // the buckets sum bit-for-bit to the measured end-to-end latency.
    let sum = a.queue + a.compute + a.transfer_wait + a.retry_backoff + a.waterfall;
    assert_eq!(sum, a.total(), "{ctx}: request {} buckets must sum exactly to e2e", a.id);
    assert_eq!(a.bucket_sum(), a.total(), "{ctx}: bucket_sum mirrors the field sum");
    for (name, d) in [
        ("queue", a.queue),
        ("compute", a.compute),
        ("transfer_wait", a.transfer_wait),
        ("retry_backoff", a.retry_backoff),
        ("waterfall", a.waterfall),
    ] {
        assert!(d <= a.total(), "{ctx}: request {} bucket {name} exceeds e2e", a.id);
    }
}

#[test]
fn attribution_buckets_sum_exactly_under_faults() {
    let (cfg, store) = setup();
    let pc = profile_model(&cfg, store.clone(), 8, 7777).unwrap();
    let warm = warm_rank_from_profile(&pc);
    // The fast-mode sweep_faults cell shape: a single-homed 4-device ring
    // whose device-down scenario is the known degradation story.
    let st = LoadSettings { n_requests: 16, ..settings() };
    let mut saw_degraded = false;
    for scenario in ["baseline", "device-down", "flap", "lose-inflight"] {
        let mut scfg = ServingConfig::default().preset("buddy-rho3").unwrap();
        scfg.cache_rate = st.cache_rate;
        scfg.seed = st.seed;
        scfg.n_devices = 4;
        scfg.topology = TopologyKind::Ring;
        scfg.fault_plan = FaultPlan::scenario(scenario).unwrap();
        let process = ProcessKind::Poisson.build(&cfg, &st, 4.0);
        let (cell, _probe, _fault, trace) = run_fault_cell_traced(
            &cfg,
            store.clone(),
            &pc,
            &warm,
            scfg,
            "buddy-rho3",
            4.0,
            process,
        )
        .unwrap();
        assert_eq!(trace.attributions.len(), cell.requests_done as usize, "{scenario}");
        for a in &trace.attributions {
            assert_exact(a, scenario);
            saw_degraded |= a.degraded;
        }
        assert_exact(cell.p99_attr.as_ref().unwrap(), scenario);
    }
    assert!(saw_degraded, "fault scenarios must exercise degraded-request attribution");
}

// ---------------------------------------------------------------------
// Chrome export schema (what Perfetto actually loads)
// ---------------------------------------------------------------------

#[test]
fn chrome_export_is_schema_valid() {
    let (cfg, store) = setup();
    let (_, trace) = run_traced(&cfg, store);
    let doc = Json::parse(&trace.chrome_json).expect("Chrome trace must be valid JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());

    let mut tracks = Vec::new();
    let mut names = Vec::new();
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        match ph {
            "M" => {
                if ev.get("name").unwrap().as_str().unwrap() == "thread_name" {
                    tracks.push(
                        ev.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string(),
                    );
                }
            }
            "X" => {
                assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                names.push(ev.get("name").unwrap().as_str().unwrap().to_string());
            }
            "i" => names.push(ev.get("name").unwrap().as_str().unwrap().to_string()),
            other => panic!("unexpected phase {other:?}"),
        }
    }
    for want in ["engine", "scheduler", "host-link-0"] {
        assert!(tracks.iter().any(|t| t == want), "missing track {want:?} in {tracks:?}");
    }
    assert!(tracks.iter().any(|t| t.starts_with("request-")), "per-request tracks expected");
    for want in ["decode_step", "pin_window", "route", "transfer", "queued", "admit", "done"] {
        assert!(names.iter().any(|n| n == want), "missing event name {want:?}");
    }
}

#[test]
fn checked_in_example_trace_matches_live_schema() {
    // The docs walkthrough opens tests/data/example_trace_perfetto.json;
    // keep it loadable and structurally in sync with the live exporter.
    let text = include_str!("data/example_trace_perfetto.json");
    let doc = Json::parse(text).expect("example trace must be valid JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.iter().any(|e| e.get("ph").unwrap().as_str().unwrap() == "M"));
    assert!(events.iter().any(|e| e.get("ph").unwrap().as_str().unwrap() == "X"));
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
}
