//! Diagnostics and the machine-readable report.
//!
//! Output is byte-stable by construction: diagnostics are sorted by
//! (file, line, rule), paths use forward slashes, and the JSON renderer
//! emits a fixed field order with no floats and no timestamps — CI greps
//! the literal `"violations": 0` and diffs the artifact across runs.

use std::fmt::Write as _;

/// One finding, pointing at a file:line with a named rule.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    /// Sort key order matters: file first, then line, then rule.
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// The full result of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub suppressed: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn violations(&self) -> usize {
        self.diagnostics.len()
    }

    /// Canonical ordering; idempotent, called before every render.
    pub fn sort(&mut self) {
        self.diagnostics.sort();
        self.diagnostics.dedup();
    }

    /// Human-readable diagnostics, one `file:line: [rule] message` per
    /// line, followed by a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            let _ = writeln!(out, "{}:{}: [{}] {}", d.file, d.line, d.rule, d.message);
        }
        let _ = writeln!(
            out,
            "pallas-lint: {} files scanned, {} violations, {} suppressed",
            self.files_scanned,
            self.violations(),
            self.suppressed
        );
        out
    }

    /// The machine-readable report. Field order, separators, and
    /// indentation are part of the contract (byte-stable, grep-able).
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"tool\": \"pallas-lint\",\n");
        out.push_str("  \"schema_version\": 1,\n");
        let _ = writeln!(out, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(out, "  \"violations\": {},", self.violations());
        let _ = writeln!(out, "  \"suppressed\": {},", self.suppressed);
        if self.diagnostics.is_empty() {
            out.push_str("  \"diagnostics\": []\n");
        } else {
            out.push_str("  \"diagnostics\": [\n");
            for (i, d) in self.diagnostics.iter().enumerate() {
                out.push_str("    {\n");
                let _ = writeln!(out, "      \"rule\": {},", json_str(d.rule));
                let _ = writeln!(out, "      \"file\": {},", json_str(&d.file));
                let _ = writeln!(out, "      \"line\": {},", d.line);
                let _ = writeln!(out, "      \"message\": {}", json_str(&d.message));
                out.push_str(if i + 1 == self.diagnostics.len() { "    }\n" } else { "    },\n" });
            }
            out.push_str("  ]\n");
        }
        out.push_str("}\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_is_clean_and_greppable() {
        let mut r = Report { files_scanned: 3, suppressed: 1, diagnostics: Vec::new() };
        r.sort();
        let json = r.render_json();
        assert!(json.contains("\"violations\": 0"));
        assert!(json.contains("\"diagnostics\": []"));
        assert_eq!(json, r.render_json(), "rendering must be deterministic");
    }

    #[test]
    fn diagnostics_sort_and_escape() {
        let mut r = Report::default();
        r.diagnostics.push(Diagnostic {
            file: "b.rs".into(),
            line: 2,
            rule: "wall-clock",
            message: "say \"no\"".into(),
        });
        r.diagnostics.push(Diagnostic {
            file: "a.rs".into(),
            line: 9,
            rule: "float-sort",
            message: "m".into(),
        });
        r.sort();
        assert_eq!(r.diagnostics[0].file, "a.rs");
        let json = r.render_json();
        assert!(json.contains("\\\"no\\\""));
        assert!(json.contains("\"violations\": 2"));
    }
}
