//! pallas-lint — the determinism-contract checker for the buddymoe
//! serving stack.
//!
//! The simulator's headline guarantee is bitwise-reproducible runs: same
//! config + seed ⇒ identical traces, reports, and goldens. That guarantee
//! is carried by conventions (virtual clock, seeded RNG streams, total
//! float orderings, ordered containers in reporting paths) that the type
//! system cannot enforce and that have each been broken at least once.
//! This crate turns those conventions into deny-by-default lint rules and
//! runs as a tier-1 CI gate: `cargo run --release -p pallas-lint`.
//!
//! It walks every `.rs` file under `rust/src`, `rust/tests`,
//! `rust/benches`, and `examples/`, lexes each file with a small
//! dependency-free lexer ([`lexer`]) — comment/string/char-literal aware,
//! so rules never fire on prose — and pattern-matches the token stream
//! ([`rules`]). Diagnostics are deterministic: sorted by (file, line,
//! rule) and rendered as byte-stable JSON ([`report`]).
//!
//! # Rule catalog
//!
//! **`wall-clock`** — `Instant::now()`, `SystemTime`, or `.elapsed()`
//! anywhere outside `util/clock.rs` and the explicitly allowlisted
//! real-time intake sites. Virtual-clock time must come from
//! `util::clock::SimClock`. The PR 6 real-time batcher regression is the
//! motivating example:
//! ```text
//! // before (nondeterministic: window depends on host scheduling)
//! let deadline = Instant::now() + window;
//! // after (deterministic: the sim clock is the only time source)
//! let deadline_us = clock.now_us() + window_us;
//! ```
//!
//! **`ambient-rng`** — `thread_rng`, `rand::random`, `from_entropy`,
//! `OsRng`, `getrandom`. All randomness flows from named, seeded
//! `util::rng` streams so a run is replayable from its config.
//!
//! **`float-sort`** — `partial_cmp` used as a sort/min/max comparator,
//! or chained straight into `.unwrap*`. NaN makes `partial_cmp` panic or
//! break comparator transitivity (UB-adjacent in `sort_by`); `total_cmp`
//! is total and deterministic. The PR 4 top-k gate is the motivating
//! example:
//! ```text
//! // before (panics on a NaN router logit)
//! idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
//! // after (NaN ranks deterministically; finite behavior unchanged)
//! idx.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
//! ```
//!
//! **`unordered-iter`** — `HashMap`/`HashSet` (and the Fx variants) in
//! modules whose iteration order can reach reports, telemetry, or golden
//! output ([`rules::ORDERED_OUTPUT_PREFIXES`]). Use `BTreeMap`/`BTreeSet`
//! or collect-and-sort.
//!
//! **`trace-emission`** — `Tracer` record calls (`span`, `instant`,
//! `stall`, `begin_request`, `finish_request`) lexically inside closures
//! passed to `util::par` fan-out (`par_map`, `par_rows`) or
//! `std::thread::{spawn, scope}`. The trace contract (ROADMAP) is that
//! only single-threaded orchestration code records; worker-side emission
//! interleaves nondeterministically. This rule is a lexical tripwire —
//! emission hidden behind a helper called from a worker is caught by the
//! trace goldens, not the lint.
//!
//! **`unwrap-audit`** — bare `.unwrap()` on the library surface
//! (`rust/src`, outside `#[cfg(test)]`). The PR 7 error-handling policy:
//! fallible paths use `?` with context, infallible ones name their
//! invariant via `.expect("...")`. Poisoning propagation
//! (`.lock()/.wait()/.join()/.recv()` followed by `.unwrap()`) is
//! exempt — those unwraps forward another thread's panic.
//!
//! # Suppressions
//!
//! A violation that is *the point* of the code (e.g. the real-time
//! batcher's genuine wall-clock deadline) is silenced in place with a
//! reasoned directive on its own line or the line above:
//!
//! ```text
//! // pallas-lint: allow(wall-clock, reason = "real-time intake deadline")
//! let t0 = Instant::now();
//! ```
//!
//! The rule name must be one of the catalog above and the reason must be
//! non-empty — a malformed directive is itself a violation (rule
//! `suppression`), so suppressions cannot rot silently. Whole-file grants
//! live in `rust/lints/allow.list` (`<rule> <path>` lines), reviewed like
//! code.

pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::{lex, Lexed};
use report::{Diagnostic, Report};

/// Directories scanned by [`lint_tree`], relative to the repo root.
pub const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// A whole-file grant: (rule, repo-root-relative path).
pub type AllowEntry = (String, String);

/// Parse the `allow.list` format: one `<rule> <path>` per line, `#`
/// comments and blank lines ignored. Unknown rule names are an error —
/// the allowlist must not rot.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (n, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (rule, path) = match (parts.next(), parts.next(), parts.next()) {
            (Some(r), Some(p), None) => (r, p),
            _ => return Err(format!("allow.list:{}: expected `<rule> <path>`", n + 1)),
        };
        if !rules::RULES.contains(&rule) {
            return Err(format!("allow.list:{}: unknown rule `{rule}`", n + 1));
        }
        out.push((rule.to_string(), path.to_string()));
    }
    Ok(out)
}

/// One parsed in-source suppression directive.
struct Suppression {
    rule: String,
    /// Line of the directive comment itself.
    line: u32,
}

/// Parse `pallas-lint: allow(<rule>, reason = "...")` directives out of a
/// file's line comments. Malformed directives (unknown rule, missing or
/// empty reason, bad syntax) become diagnostics with rule `suppression`.
fn parse_suppressions(lexed: &Lexed) -> (Vec<Suppression>, Vec<(u32, String)>) {
    let mut sups = Vec::new();
    let mut bad = Vec::new();
    for c in &lexed.comments {
        let Some(rest) = c.text.strip_prefix("pallas-lint:") else { continue };
        let rest = rest.trim();
        let inner = rest
            .strip_prefix("allow(")
            .and_then(|s| s.strip_suffix(')'))
            .map(str::trim);
        let Some(inner) = inner else {
            bad.push((c.line, format!("malformed directive `{}`", c.text)));
            continue;
        };
        let (rule, tail) = match inner.split_once(',') {
            Some((r, t)) => (r.trim(), t.trim()),
            None => (inner, ""),
        };
        if !rules::RULES.contains(&rule) {
            bad.push((c.line, format!("unknown rule `{rule}` in suppression")));
            continue;
        }
        let reason = tail
            .strip_prefix("reason")
            .map(str::trim_start)
            .and_then(|s| s.strip_prefix('='))
            .map(str::trim)
            .and_then(|s| s.strip_prefix('"'))
            .and_then(|s| s.strip_suffix('"'));
        match reason {
            Some(r) if !r.trim().is_empty() => {
                sups.push(Suppression { rule: rule.to_string(), line: c.line });
            }
            _ => bad.push((
                c.line,
                format!("suppression of `{rule}` needs a non-empty reason = \"...\""),
            )),
        }
    }
    (sups, bad)
}

/// Lint one file's source. `path` is the repo-root-relative,
/// `/`-separated label (it scopes path-sensitive rules and is matched
/// against `allow`). Returns the surviving diagnostics and how many
/// findings were silenced by suppressions or the allowlist.
pub fn lint_source(path: &str, src: &str, allow: &[AllowEntry]) -> (Vec<Diagnostic>, usize) {
    let lexed = lex(src);
    let findings = rules::run_all(path, &lexed.tokens);
    let (sups, bad) = parse_suppressions(&lexed);

    // A directive covers its own line plus the next token-bearing line
    // (comments emit no tokens, so stacked directives all reach the code).
    let mut token_lines: Vec<u32> = lexed.tokens.iter().map(|t| t.line).collect();
    token_lines.sort_unstable();
    token_lines.dedup();
    let next_code_line = |line: u32| -> Option<u32> {
        let at = token_lines.partition_point(|&l| l <= line);
        token_lines.get(at).copied()
    };
    let covers = |s: &Suppression, rule: &str, line: u32| -> bool {
        s.rule == rule && (line == s.line || Some(line) == next_code_line(s.line))
    };

    let file_allowed = |rule: &str| allow.iter().any(|(r, p)| r == rule && p == path);

    let mut out = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        if sups.iter().any(|s| covers(s, f.rule, f.line)) || file_allowed(f.rule) {
            suppressed += 1;
        } else {
            out.push(Diagnostic {
                file: path.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
            });
        }
    }
    for (line, message) in bad {
        out.push(Diagnostic { file: path.to_string(), line, rule: "suppression", message });
    }
    out.sort();
    (out, suppressed)
}

/// Recursively collect `.rs` files under `dir`, sorted by name at every
/// level so the scan order (and thus the report) is deterministic.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-root-relative label with forward slashes, for stable reports and
/// path-scoped rules regardless of host OS.
fn label_for(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Lint every `.rs` file under [`SCAN_ROOTS`] below `root`. Missing scan
/// roots are skipped (the crate must work from a partial checkout);
/// unreadable files are hard errors.
pub fn lint_tree(root: &Path, allow: &[AllowEntry]) -> io::Result<Report> {
    let mut files = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut report = Report::default();
    for file in files {
        let src = fs::read_to_string(&file)?;
        let (diags, suppressed) = lint_source(&label_for(root, &file), &src, allow);
        report.files_scanned += 1;
        report.suppressed += suppressed;
        report.diagnostics.extend(diags);
    }
    report.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_rejects_unknown_rules() {
        assert!(parse_allowlist("wall-clock rust/src/util/clock.rs\n").is_ok());
        assert!(parse_allowlist("no-such-rule a.rs\n").is_err());
        assert!(parse_allowlist("wall-clock\n").is_err());
        let with_comment = "# grants\nunwrap-audit rust/src/weights/store.rs # builder\n";
        assert_eq!(parse_allowlist(with_comment).map(|v| v.len()), Ok(1));
    }

    #[test]
    fn suppression_covers_own_and_next_line() {
        let src = "// pallas-lint: allow(wall-clock, reason = \"intake deadline\")\n\
                   let t = Instant::now();\n\
                   let u = Instant::now();\n";
        let (diags, suppressed) = lint_source("rust/src/x.rs", src, &[]);
        assert_eq!(suppressed, 1);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 3);
    }

    #[test]
    fn trailing_suppression_covers_its_own_line() {
        let src =
            "let t = Instant::now(); // pallas-lint: allow(wall-clock, reason = \"deadline\")\n";
        let (diags, suppressed) = lint_source("rust/src/x.rs", src, &[]);
        assert_eq!((diags.len(), suppressed), (0, 1));
    }

    #[test]
    fn reasonless_suppression_is_a_violation() {
        let src = "// pallas-lint: allow(wall-clock)\nlet t = Instant::now();\n";
        let (diags, suppressed) = lint_source("rust/src/x.rs", src, &[]);
        assert_eq!(suppressed, 0, "a reasonless directive must not suppress");
        let rules: Vec<_> = diags.iter().map(|d| d.rule).collect();
        assert!(rules.contains(&"suppression"));
        assert!(rules.contains(&"wall-clock"));
    }

    #[test]
    fn file_allowlist_silences_matching_rule_only() {
        let allow = vec![("wall-clock".to_string(), "rust/src/x.rs".to_string())];
        let src = "let t = Instant::now();\nlet v = x.unwrap();\n";
        let (diags, suppressed) = lint_source("rust/src/x.rs", src, &allow);
        assert_eq!(suppressed, 1);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "unwrap-audit");
    }
}
