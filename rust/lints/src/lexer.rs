//! A minimal, dependency-free Rust lexer — just enough fidelity for
//! contract linting.
//!
//! The token stream keeps identifiers/keywords and single-byte
//! punctuation with their 1-based line numbers, and drops everything a
//! rule could false-positive on: whitespace, comments (collected
//! separately so suppression directives can be parsed), string/char/byte
//! literals (including raw strings and raw identifiers), and numeric
//! literals. The classic `'a'`-char vs `'a`-lifetime ambiguity is
//! resolved the same way rustc's lexer does: a quote starts a char
//! literal only when an escape follows or the quote closes one character
//! later.
//!
//! Fidelity limits are deliberate (this is a tripwire, not a compiler):
//! non-ASCII identifiers and exotic numeric suffixes may lex as several
//! junk tokens, which no rule pattern matches, so they cannot produce
//! diagnostics — only, at worst, missed ones.

/// One lexical token: an identifier/keyword, or one punctuation byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub text: String,
    pub punct: bool,
    /// 1-based source line.
    pub line: u32,
}

impl Token {
    pub fn is_punct(&self, c: char) -> bool {
        self.punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }

    pub fn is_ident(&self, name: &str) -> bool {
        !self.punct && self.text == name
    }
}

/// A `//` line comment (text after the slashes, trimmed), with its line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommentLine {
    pub text: String,
    pub line: u32,
}

/// The lexed view of one source file.
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<CommentLine>,
}

/// Lex `src` into tokens and line comments. Never fails: unrecognized
/// bytes become punctuation tokens no rule matches.
pub fn lex(src: &str) -> Lexed {
    Lexer { b: src.as_bytes(), src, i: 0, line: 1, tokens: Vec::new(), comments: Vec::new() }
        .run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: u32,
    tokens: Vec<Token>,
    comments: Vec<CommentLine>,
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> u8 {
        *self.b.get(self.i + ahead).unwrap_or(&0)
    }

    fn run(mut self) -> Lexed {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.peek(1) == b'/' => self.line_comment(),
                b'/' if self.peek(1) == b'*' => self.block_comment(),
                b'"' => {
                    self.i += 1;
                    self.quoted_string();
                }
                b'\'' => self.char_or_lifetime(),
                b'r' | b'b' if self.literal_prefix_len().is_some() => self.prefixed_literal(),
                b'_' => self.ident(),
                _ if c.is_ascii_alphabetic() => self.ident(),
                _ if c.is_ascii_digit() => self.number(),
                _ => {
                    self.push_punct(c);
                    self.i += 1;
                }
            }
        }
        Lexed { tokens: self.tokens, comments: self.comments }
    }

    fn push_punct(&mut self, c: u8) {
        self.tokens.push(Token { text: (c as char).to_string(), punct: true, line: self.line });
    }

    fn line_comment(&mut self) {
        let start = self.i + 2;
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        let text = self.src[start..self.i].trim().to_string();
        self.comments.push(CommentLine { text, line: self.line });
    }

    fn block_comment(&mut self) {
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            if self.b[self.i] == b'\n' {
                self.line += 1;
                self.i += 1;
            } else if self.b[self.i] == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.i += 2;
            } else if self.b[self.i] == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.i += 2;
            } else {
                self.i += 1;
            }
        }
    }

    /// Body of a non-raw string/byte-string; `self.i` is past the
    /// opening quote on entry and past the closing quote on exit.
    fn quoted_string(&mut self) {
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => {
                    if self.peek(1) == b'\n' {
                        self.line += 1;
                    }
                    self.i += 2;
                }
                b'"' => {
                    self.i += 1;
                    return;
                }
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// `'x'`, `'\n'`, `'\u{1F600}'` are char literals; `'a` / `'_` are
    /// lifetimes (skipped — rules never match them).
    fn char_or_lifetime(&mut self) {
        let j = self.i + 1;
        if j >= self.b.len() {
            self.i = j;
        } else if self.b[j] == b'\\' {
            let mut k = j + 1;
            if self.peek(2) == b'u' && self.peek(3) == b'{' {
                k += 2;
                while k < self.b.len() && self.b[k] != b'}' {
                    k += 1;
                }
            }
            k += 1;
            // Closing quote (tolerate malformed input by not requiring it).
            if k < self.b.len() && self.b[k] == b'\'' {
                k += 1;
            }
            self.i = k;
        } else if j + 1 < self.b.len() && self.b[j] != b'\'' && self.b[j + 1] == b'\'' {
            self.i = j + 2;
        } else {
            self.i = j;
            while self.i < self.b.len()
                && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
            {
                self.i += 1;
            }
        }
    }

    /// If the cursor sits on an `r`/`b`-prefixed literal (`r"`, `r#"`,
    /// `b"`, `b'`, `br#"` ...), the prefix length up to but excluding the
    /// opening quote; `None` when it is just an identifier like `ring`.
    /// `r#ident` raw identifiers also return `None`.
    fn literal_prefix_len(&self) -> Option<usize> {
        let mut k = 0usize;
        if self.peek(k) == b'b' {
            k += 1;
            if self.peek(k) == b'\'' {
                return Some(k);
            }
            if self.peek(k) == b'r' {
                k += 1;
            }
        } else if self.peek(k) == b'r' {
            k += 1;
        } else {
            return None;
        }
        while self.peek(k) == b'#' {
            k += 1;
        }
        // `r#foo` (raw identifier) has hashes but no quote after them,
        // and plain identifiers like `ring`/`by` have neither — both
        // fall through to None and lex as identifiers.
        if self.peek(k) == b'"' {
            return Some(k);
        }
        None
    }

    fn prefixed_literal(&mut self) {
        let quote_at = self.i + self.literal_prefix_len().expect("caller checked prefix");
        if self.b[quote_at] == b'\'' {
            // Byte char literal b'x' / b'\n'.
            self.i = quote_at + 1;
            if self.peek(0) == b'\\' {
                self.i += 2;
            } else {
                self.i += 1;
            }
            if self.peek(0) == b'\'' {
                self.i += 1;
            }
            return;
        }
        let raw = self.src[self.i..quote_at].contains('r');
        let hashes = self.src[self.i..quote_at].matches('#').count();
        self.i = quote_at + 1;
        if !raw {
            self.quoted_string();
            return;
        }
        // Raw string: ends at `"` followed by `hashes` hashes, no escapes.
        while self.i < self.b.len() {
            if self.b[self.i] == b'\n' {
                self.line += 1;
                self.i += 1;
            } else if self.b[self.i] == b'"'
                && self.b[self.i + 1..].iter().take_while(|&&h| h == b'#').count() >= hashes
            {
                self.i += 1 + hashes;
                return;
            } else {
                self.i += 1;
            }
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        while self.i < self.b.len()
            && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
        {
            self.i += 1;
        }
        self.tokens.push(Token {
            text: self.src[start..self.i].to_string(),
            punct: false,
            line: self.line,
        });
    }

    /// Numeric literals produce no tokens — no rule matches numbers, and
    /// dropping them keeps suffixes (`1.0f32`, `0xfe`, `1e-3`) from
    /// surfacing as spurious identifiers.
    fn number(&mut self) {
        while self.i < self.b.len()
            && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
        {
            self.i += 1;
        }
        if self.i + 1 < self.b.len()
            && self.b[self.i] == b'.'
            && self.b[self.i + 1].is_ascii_digit()
        {
            self.i += 1;
            while self.i < self.b.len()
                && (self.b[self.i].is_ascii_alphanumeric() || self.b[self.i] == b'_')
            {
                self.i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().filter(|t| !t.punct).map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_invisible() {
        let src = r##"
            // Instant::now in a comment
            /* SystemTime in /* a nested */ block */
            let s = "Instant::now()";
            let r = r#"thread_rng "quoted" here"#;
            let b = b"partial_cmp";
            call(real_token);
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_token".to_string()));
        assert!(!ids.iter().any(|t| t.contains("Instant")));
        assert!(!ids.iter().any(|t| t.contains("thread_rng")));
        assert!(!ids.iter().any(|t| t.contains("partial_cmp")));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ids = idents("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; g(c, n) }");
        assert!(ids.contains(&"str".to_string()));
        assert!(ids.contains(&"g".to_string()));
        // 'x' must not swallow the rest of the line as a string would.
        assert!(ids.contains(&"n".to_string()));
    }

    #[test]
    fn comment_lines_are_collected() {
        let out = lex("let a = 1; // pallas-lint: allow(wall-clock, reason = \"x\")\nlet b = 2;");
        assert_eq!(out.comments.len(), 1);
        assert_eq!(out.comments[0].line, 1);
        assert!(out.comments[0].text.starts_with("pallas-lint:"));
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let s = \"line\none\ntwo\";\nInstant::now();";
        let toks = lex(src).tokens;
        let inst = toks.iter().find(|t| t.is_ident("Instant")).expect("Instant token");
        assert_eq!(inst.line, 4);
    }

    #[test]
    fn raw_identifiers_still_lex() {
        let ids = idents("let r#type = 1; use_it(r#type);");
        assert!(ids.contains(&"use_it".to_string()));
    }
}
