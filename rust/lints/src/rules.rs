//! The six determinism-contract rules, as token-stream passes.
//!
//! Every rule is deny-by-default and named; see the crate docs for the
//! catalog with before/after examples, and `suppress.rs` for the scoped
//! escape hatch. Rules return raw `(rule, line, message)` findings; the
//! driver in `lib.rs` applies suppressions and the file allowlist.

use crate::lexer::Token;

/// Rule names, in catalog order. `RULES` is the closed set a suppression
/// or allowlist entry may name.
pub const RULES: [&str; 6] = [
    "wall-clock",
    "ambient-rng",
    "float-sort",
    "unordered-iter",
    "trace-emission",
    "unwrap-audit",
];

/// Sort-family methods whose comparator argument must be NaN-safe.
const SORT_METHODS: [&str; 6] = [
    "sort_by",
    "sort_unstable_by",
    "select_nth_unstable_by",
    "min_by",
    "max_by",
    "binary_search_by",
];

/// `Tracer` recording entry points (see `trace/recorder.rs`).
const TRACER_METHODS: [&str; 5] = ["span", "instant", "stall", "begin_request", "finish_request"];

/// Fan-out / thread entry points whose closures run off the orchestration
/// thread: `util::par` and `std::thread`.
const FANOUT_CALLS: [&str; 4] = ["par_map", "par_rows", "spawn", "scope"];

/// `.unwrap()` callees that propagate another thread's panic (mutex /
/// condvar poisoning, thread join): sanctioned, since inventing a message
/// for "a thread already panicked" adds nothing.
const POISON_CALLEES: [&str; 5] = ["lock", "wait", "wait_timeout", "join", "recv"];

/// Hash containers whose iteration order is nondeterministic.
const HASH_TYPES: [&str; 4] = ["HashMap", "HashSet", "FxHashMap", "FxHashSet"];

/// Module path prefixes (repo-root-relative, `/`-separated) where
/// iteration order can reach reports, telemetry, or golden output — the
/// scope of the `unordered-iter` rule. `util`, `config`, `weights`,
/// `testing`, and integration tests are deliberately outside it: a hash
/// container is fine where order provably never escapes.
pub const ORDERED_OUTPUT_PREFIXES: [&str; 16] = [
    "rust/src/server/",
    "rust/src/trace/",
    "rust/src/stats/",
    "rust/src/traffic/",
    "rust/src/model/",
    "rust/src/memory/",
    "rust/src/buddy/",
    "rust/src/topology/",
    "rust/src/fault/",
    "rust/src/eval/",
    "rust/src/prefetch/",
    "rust/src/profilecollect/",
    "rust/src/runtime/",
    "rust/src/main.rs",
    "rust/benches/",
    "examples/",
];

/// A raw finding before suppression/allowlist filtering.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Run every rule over one file's token stream. `path` is the
/// repo-root-relative, `/`-separated label (it scopes `unordered-iter`
/// and `unwrap-audit`).
pub fn run_all(path: &str, toks: &[Token]) -> Vec<Finding> {
    let mut out = Vec::new();
    wall_clock(toks, &mut out);
    ambient_rng(toks, &mut out);
    float_sort(toks, &mut out);
    unordered_iter(path, toks, &mut out);
    trace_emission(toks, &mut out);
    unwrap_audit(path, toks, &mut out);
    out.sort();
    out.dedup();
    out
}

fn is_p(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i).is_some_and(|t| t.is_punct(c))
}

fn is_i(toks: &[Token], i: usize, name: &str) -> bool {
    toks.get(i).is_some_and(|t| t.is_ident(name))
}

fn ident_in(toks: &[Token], i: usize, set: &[&str]) -> bool {
    toks.get(i).is_some_and(|t| !t.punct && set.contains(&t.text.as_str()))
}

/// Index of the close paren matching the open paren at `open`, scanning
/// forward; `None` on unbalanced input.
fn match_paren_forward(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Index of the open paren matching the close paren at `close`, scanning
/// backward; `None` on unbalanced input.
fn match_paren_backward(toks: &[Token], close: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().take(close + 1).rev() {
        if t.is_punct(')') {
            depth += 1;
        } else if t.is_punct('(') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Index of the close brace matching the open brace at `open`.
fn match_brace_forward(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Inclusive line ranges of `#[cfg(test)]`-gated items: from the
/// attribute to the end of the next braced block. `cfg` predicates
/// containing `not` (e.g. `cfg(not(test))`) are conservatively treated
/// as non-test.
pub fn test_regions(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 4 < toks.len() {
        if is_p(toks, i, '#')
            && is_p(toks, i + 1, '[')
            && is_i(toks, i + 2, "cfg")
            && is_p(toks, i + 3, '(')
        {
            if let Some(close) = match_paren_forward(toks, i + 3) {
                let pred = &toks[i + 4..close];
                let has_test = pred.iter().any(|t| t.is_ident("test"));
                let has_not = pred.iter().any(|t| t.is_ident("not"));
                if has_test && !has_not {
                    let mut j = close + 1;
                    while j < toks.len() && !toks[j].is_punct('{') {
                        j += 1;
                    }
                    if j < toks.len() {
                        if let Some(end) = match_brace_forward(toks, j) {
                            out.push((toks[i].line, toks[end].line));
                            i = end + 1;
                            continue;
                        }
                    }
                }
            }
        }
        i += 1;
    }
    out
}

fn in_regions(regions: &[(u32, u32)], line: u32) -> bool {
    regions.iter().any(|&(a, b)| a <= line && line <= b)
}

/// wall-clock: `Instant::now`, `SystemTime`, and `.elapsed(` — serving
/// code must read time from `util::clock::SimClock`.
fn wall_clock(toks: &[Token], out: &mut Vec<Finding>) {
    for k in 0..toks.len() {
        if is_i(toks, k, "Instant")
            && is_p(toks, k + 1, ':')
            && is_p(toks, k + 2, ':')
            && is_i(toks, k + 3, "now")
        {
            push(out, "wall-clock", toks[k].line, "`Instant::now()` outside util/clock.rs");
        }
        if is_i(toks, k, "SystemTime") {
            push(out, "wall-clock", toks[k].line, "`SystemTime` outside util/clock.rs");
        }
        if is_p(toks, k, '.') && is_i(toks, k + 1, "elapsed") && is_p(toks, k + 2, '(') {
            push(out, "wall-clock", toks[k].line, "`.elapsed()` wall-clock read");
        }
    }
}

/// ambient-rng: all randomness must come from a seeded `util::rng`
/// stream — no thread-local or OS entropy.
fn ambient_rng(toks: &[Token], out: &mut Vec<Finding>) {
    const AMBIENT: [&str; 4] = ["thread_rng", "from_entropy", "OsRng", "getrandom"];
    for k in 0..toks.len() {
        if ident_in(toks, k, &AMBIENT) {
            let msg = format!("ambient RNG `{}`: use a seeded util::rng stream", toks[k].text);
            push_owned(out, "ambient-rng", toks[k].line, msg);
        }
        if is_i(toks, k, "rand")
            && is_p(toks, k + 1, ':')
            && is_p(toks, k + 2, ':')
            && is_i(toks, k + 3, "random")
        {
            push(out, "ambient-rng", toks[k].line, "`rand::random`: use a seeded util::rng stream");
        }
    }
}

/// float-sort: a float comparator built from `partial_cmp` is
/// NaN-unsafe (panics or silently breaks transitivity). Two patterns:
/// `partial_cmp` lexically inside a sort-family call's arguments, and
/// `.partial_cmp(..)` chained straight into `.unwrap*`.
fn float_sort(toks: &[Token], out: &mut Vec<Finding>) {
    const MSG: &str = "NaN-unsafe `partial_cmp` comparator: use `total_cmp` (PR 4/6 policy)";
    for k in 0..toks.len() {
        if ident_in(toks, k, &SORT_METHODS) && is_p(toks, k + 1, '(') {
            if let Some(close) = match_paren_forward(toks, k + 1) {
                for t in &toks[k + 2..close] {
                    if t.is_ident("partial_cmp") {
                        push(out, "float-sort", t.line, MSG);
                    }
                }
            }
        }
        if is_p(toks, k, '.') && is_i(toks, k + 1, "partial_cmp") && is_p(toks, k + 2, '(') {
            if let Some(close) = match_paren_forward(toks, k + 2) {
                let chained_unwrap = is_p(toks, close + 1, '.')
                    && toks
                        .get(close + 2)
                        .is_some_and(|t| !t.punct && t.text.starts_with("unwrap"));
                if chained_unwrap {
                    push(out, "float-sort", toks[k + 1].line, MSG);
                }
            }
        }
    }
}

/// unordered-iter: hash containers are banned where iteration order can
/// reach output (see [`ORDERED_OUTPUT_PREFIXES`]).
fn unordered_iter(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let scoped = ORDERED_OUTPUT_PREFIXES
        .iter()
        .any(|p| path.starts_with(p) || path == p.trim_end_matches('/'));
    if !scoped {
        return;
    }
    for t in toks {
        if !t.punct && HASH_TYPES.contains(&t.text.as_str()) {
            let msg = format!(
                "`{}` in an ordered-output module: iteration order leaks into \
                 reports; use BTreeMap/BTreeSet or collect-and-sort",
                t.text
            );
            push_owned(out, "unordered-iter", t.line, msg);
        }
    }
}

/// trace-emission: `Tracer` record calls are only sound from
/// single-threaded orchestration code; flag them lexically inside
/// closures passed to `util::par` fan-out or `std::thread` spawn/scope.
/// (A tripwire, not a proof: emission hidden behind a function called
/// from a worker still needs the `tests/trace.rs` golden to catch it.)
fn trace_emission(toks: &[Token], out: &mut Vec<Finding>) {
    for k in 0..toks.len() {
        if ident_in(toks, k, &FANOUT_CALLS) && is_p(toks, k + 1, '(') {
            if let Some(close) = match_paren_forward(toks, k + 1) {
                for m in k + 2..close.saturating_sub(1) {
                    if is_p(toks, m, '.')
                        && ident_in(toks, m + 1, &TRACER_METHODS)
                        && is_p(toks, m + 2, '(')
                    {
                        let msg = format!(
                            "Tracer `.{}()` inside a fan-out/spawned closure: only \
                             single-threaded orchestration code may record",
                            toks[m + 1].text
                        );
                        push_owned(out, "trace-emission", toks[m + 1].line, msg);
                    }
                }
            }
        }
    }
}

/// unwrap-audit: bare `.unwrap()` on the library surface (`rust/src`,
/// outside `#[cfg(test)]`) — use `?` with context or
/// `.expect("named invariant")` per the PR 7 policy. Poisoning
/// propagation (`lock/wait/join/recv`) is exempt.
fn unwrap_audit(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    if !path.starts_with("rust/src/") {
        return;
    }
    let regions = test_regions(toks);
    for k in 0..toks.len() {
        let bare_unwrap = is_p(toks, k, '.')
            && is_i(toks, k + 1, "unwrap")
            && is_p(toks, k + 2, '(')
            && is_p(toks, k + 3, ')');
        if !bare_unwrap || in_regions(&regions, toks[k].line) {
            continue;
        }
        let exempt = k > 0
            && toks[k - 1].is_punct(')')
            && match_paren_backward(toks, k - 1)
                .and_then(|open| open.checked_sub(1))
                .is_some_and(|callee| ident_in(toks, callee, &POISON_CALLEES));
        if !exempt {
            push(
                out,
                "unwrap-audit",
                toks[k].line,
                "bare `.unwrap()` on the library surface: use `?` with context or \
                 `.expect(\"named invariant\")` (PR 7 policy)",
            );
        }
    }
}

fn push(out: &mut Vec<Finding>, rule: &'static str, line: u32, msg: &str) {
    out.push(Finding { line, rule, message: msg.to_string() });
}

fn push_owned(out: &mut Vec<Finding>, rule: &'static str, line: u32, message: String) {
    out.push(Finding { line, rule, message });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn findings(path: &str, src: &str) -> Vec<(&'static str, u32)> {
        run_all(path, &lex(src).tokens).into_iter().map(|f| (f.rule, f.line)).collect()
    }

    #[test]
    fn wall_clock_patterns() {
        let got = findings(
            "rust/src/x.rs",
            "fn f() {\n    let t = Instant::now();\n    let d = t.elapsed();\n}\n",
        );
        assert_eq!(got, vec![("wall-clock", 2), ("wall-clock", 3)]);
    }

    #[test]
    fn float_sort_catches_comparator_variables() {
        // The PR 4 shape: partial_cmp in a named closure, only *used* by
        // the sort — pattern (b) catches the definition site.
        let src = "let by = |a: &f32, b: &f32| a.partial_cmp(b).unwrap_or(Ordering::Equal);\n\
                   v.sort_by(by);\n";
        assert_eq!(findings("rust/tests/t.rs", src), vec![("float-sort", 1)]);
    }

    #[test]
    fn poisoning_unwrap_is_exempt() {
        let src = "fn f(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n\
                   fn g(v: &[u32]) -> u32 {\n    *v.first().unwrap()\n}\n";
        assert_eq!(findings("rust/src/x.rs", src), vec![("unwrap-audit", 5)]);
    }

    #[test]
    fn unwrap_outside_src_is_out_of_scope() {
        assert!(findings("rust/tests/t.rs", "fn f() { x.unwrap(); }").is_empty());
    }

    #[test]
    fn cfg_test_mod_is_exempt_from_unwrap_audit() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        \
                   x.unwrap();\n    }\n}\n";
        assert!(findings("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn unordered_iter_is_scoped() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(findings("rust/src/server/m.rs", src), vec![("unordered-iter", 1)]);
        assert!(findings("rust/src/util/m.rs", src).is_empty());
    }

    #[test]
    fn tracer_in_fanout_closure() {
        let src = "par_rows(out, 4, w, |r, c| {\n    tracer.instant(\"x\", 0, &[]);\n});\n";
        assert_eq!(findings("rust/src/x.rs", src), vec![("trace-emission", 2)]);
        // The same call from straight-line orchestration code is fine.
        assert!(findings("rust/src/x.rs", "tracer.instant(\"x\", 0, &[]);\n").is_empty());
    }
}
