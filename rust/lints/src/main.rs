//! CLI for pallas-lint. Tier-1 CI gate:
//!
//! ```text
//! cargo run --release -p pallas-lint -- --json pallas-lint.json
//! ```
//!
//! Exits 0 on a clean tree, 1 on any violation, 2 on usage/IO errors.
//! Human diagnostics go to stdout; `--json <file>` additionally writes
//! the byte-stable machine report (CI greps it for `"violations": 0`).

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{lint_tree, parse_allowlist};

struct Opts {
    root: PathBuf,
    json: Option<PathBuf>,
    allowlist: Option<PathBuf>,
}

fn usage() -> &'static str {
    "usage: pallas-lint [--root <repo-root>] [--json <out.json>] [--allowlist <file>]\n\
     \n\
     Scans rust/src, rust/tests, rust/benches, examples under the repo root\n\
     for determinism-contract violations. Default root is the workspace's\n\
     parent (the repo checkout); default allowlist is rust/lints/allow.list."
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    // Default root: rust/lints/../.. == the repo checkout.
    let mut opts = Opts {
        root: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../.."),
        json: None,
        allowlist: None,
    };
    let mut i = 0usize;
    while i < args.len() {
        let value = |i: usize, name: &str| -> Result<PathBuf, String> {
            args.get(i + 1).map(PathBuf::from).ok_or_else(|| format!("{name} needs a value"))
        };
        match args[i].as_str() {
            "--root" => {
                opts.root = value(i, "--root")?;
                i += 2;
            }
            "--json" => {
                opts.json = Some(value(i, "--json")?);
                i += 2;
            }
            "--allowlist" => {
                opts.allowlist = Some(value(i, "--allowlist")?);
                i += 2;
            }
            "--help" | "-h" => return Err(String::new()),
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(opts)
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = parse_opts(&args)?;

    let allow_path =
        opts.allowlist.clone().unwrap_or_else(|| opts.root.join("rust/lints/allow.list"));
    let allow = if allow_path.is_file() {
        let text = std::fs::read_to_string(&allow_path)
            .map_err(|e| format!("reading {}: {e}", allow_path.display()))?;
        parse_allowlist(&text)?
    } else if opts.allowlist.is_some() {
        return Err(format!("allowlist {} not found", allow_path.display()));
    } else {
        Vec::new()
    };

    let report = lint_tree(&opts.root, &allow)
        .map_err(|e| format!("scanning {}: {e}", opts.root.display()))?;

    print!("{}", report.render_human());
    if let Some(json_path) = &opts.json {
        std::fs::write(json_path, report.render_json())
            .map_err(|e| format!("writing {}: {e}", json_path.display()))?;
    }
    Ok(report.violations() == 0)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(msg) => {
            if msg.is_empty() {
                println!("{}", usage());
                ExitCode::SUCCESS
            } else {
                eprintln!("pallas-lint: {msg}");
                eprintln!("{}", usage());
                ExitCode::from(2)
            }
        }
    }
}
