//! Golden fixture suite for pallas-lint.
//!
//! Each `tests/fixtures/*.rs` file is lexed as *data* (cargo never
//! compiles it) and must produce exactly the diagnostics its header
//! comment promises — rule and line both. On top of the fixtures: the
//! JSON rendering is asserted byte-for-byte, and the real tree is linted
//! as a self-check so the gate can never silently drift from the code.

use std::path::PathBuf;

use pallas_lint::report::{Diagnostic, Report};
use pallas_lint::{lint_source, lint_tree, parse_allowlist};

/// Lint fixture source under a pretend repo path; return ((rule, line)s,
/// suppressed-count).
fn check(path_label: &str, src: &str) -> (Vec<(&'static str, u32)>, usize) {
    let (diags, suppressed) = lint_source(path_label, src, &[]);
    (diags.iter().map(|d| (d.rule, d.line)).collect(), suppressed)
}

#[test]
fn fixture_wall_clock() {
    let (d, s) = check("rust/src/fixture.rs", include_str!("fixtures/bad_wall_clock.rs"));
    assert_eq!(d, vec![("wall-clock", 5), ("wall-clock", 6), ("wall-clock", 7)]);
    assert_eq!(s, 0);
}

#[test]
fn fixture_ambient_rng() {
    let (d, s) = check("rust/src/fixture.rs", include_str!("fixtures/bad_ambient_rng.rs"));
    assert_eq!(d, vec![("ambient-rng", 5), ("ambient-rng", 6)]);
    assert_eq!(s, 0);
}

#[test]
fn fixture_float_sort() {
    // Also fires outside rust/src — tests and benches sort floats too.
    let (d, s) = check("rust/tests/fixture.rs", include_str!("fixtures/bad_float_sort.rs"));
    assert_eq!(d, vec![("float-sort", 5), ("float-sort", 6)]);
    assert_eq!(s, 0);
}

#[test]
fn fixture_unordered_iter() {
    let src = include_str!("fixtures/bad_unordered_iter.rs");
    let (d, s) = check("rust/src/server/bad_unordered_iter.rs", src);
    assert_eq!(d, vec![("unordered-iter", 6), ("unordered-iter", 8)]);
    assert_eq!(s, 0);
    // The same source outside the ordered-output scope is clean.
    let (d, _) = check("rust/src/util/fixture.rs", src);
    assert!(d.is_empty());
}

#[test]
fn fixture_trace_emission() {
    let (d, s) = check("rust/src/fixture.rs", include_str!("fixtures/bad_trace_emission.rs"));
    assert_eq!(d, vec![("trace-emission", 7)]);
    assert_eq!(s, 0);
}

#[test]
fn fixture_admission() {
    // The admission module lives under rust/src/server/ — inside the
    // ordered-output scope (its shed log and EWMA state feed
    // byte-identical reports), and its trace emissions must stay on the
    // single-threaded orchestration side.
    let src = include_str!("fixtures/bad_admission.rs");
    let (d, s) = check("rust/src/server/bad_admission.rs", src);
    assert_eq!(
        d,
        vec![("unordered-iter", 7), ("unordered-iter", 9), ("trace-emission", 15)]
    );
    assert_eq!(s, 0);
    // Outside the ordered-output scope only the trace rule remains.
    let (d, _) = check("rust/src/util/fixture.rs", src);
    assert_eq!(d, vec![("trace-emission", 15)]);
}

#[test]
fn fixture_unwrap() {
    let src = include_str!("fixtures/bad_unwrap.rs");
    let (d, s) = check("rust/src/fixture.rs", src);
    assert_eq!(d, vec![("unwrap-audit", 6)]);
    assert_eq!(s, 0);
    // unwrap-audit is library-surface only: the same source in tests/ is clean.
    let (d, _) = check("rust/tests/fixture.rs", src);
    assert!(d.is_empty());
}

#[test]
fn fixture_suppressed() {
    let (d, s) = check("rust/src/fixture.rs", include_str!("fixtures/suppressed.rs"));
    assert_eq!(d, vec![("suppression", 8), ("wall-clock", 9)]);
    assert_eq!(s, 2, "two reasoned directives must each silence one finding");
}

#[test]
fn json_report_is_byte_stable() {
    let mut r = Report {
        files_scanned: 2,
        suppressed: 1,
        diagnostics: vec![Diagnostic {
            file: "rust/src/a.rs".to_string(),
            line: 3,
            rule: "wall-clock",
            message: "`Instant::now()` outside util/clock.rs".to_string(),
        }],
    };
    r.sort();
    let want = concat!(
        "{\n",
        "  \"tool\": \"pallas-lint\",\n",
        "  \"schema_version\": 1,\n",
        "  \"files_scanned\": 2,\n",
        "  \"violations\": 1,\n",
        "  \"suppressed\": 1,\n",
        "  \"diagnostics\": [\n",
        "    {\n",
        "      \"rule\": \"wall-clock\",\n",
        "      \"file\": \"rust/src/a.rs\",\n",
        "      \"line\": 3,\n",
        "      \"message\": \"`Instant::now()` outside util/clock.rs\"\n",
        "    }\n",
        "  ]\n",
        "}\n",
    );
    assert_eq!(r.render_json(), want);
    assert_eq!(r.render_json(), r.render_json(), "rendering must be deterministic");
}

/// The gate itself: the real tree must lint clean under the checked-in
/// allowlist. This is what makes seeding an `Instant::now()` or a
/// `partial_cmp` sort into rust/src fail CI even before the dedicated
/// lint step runs.
#[test]
fn real_tree_lints_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let allow_text = std::fs::read_to_string(root.join("rust/lints/allow.list"))
        .expect("rust/lints/allow.list is checked in");
    let allow = parse_allowlist(&allow_text).expect("allow.list parses");
    let report = lint_tree(&root, &allow).expect("tree scan succeeds");
    assert!(report.files_scanned > 50, "scan must actually find the tree");
    assert_eq!(
        report.violations(),
        0,
        "tree must lint clean; diagnostics:\n{}",
        report.render_human()
    );
}

/// Seeding a violation into an otherwise-clean source must be caught —
/// the acceptance test for the gate, in miniature.
#[test]
fn seeded_violation_is_caught() {
    let clean = "fn orchestrate(clock: &SimClock) -> u64 {\n    clock.now_us()\n}\n";
    let (d, _) = check("rust/src/server/loop.rs", clean);
    assert!(d.is_empty());
    let seeded = format!("{clean}fn leak() -> std::time::Instant {{\n    Instant::now()\n}}\n");
    let (d, _) = check("rust/src/server/loop.rs", &seeded);
    assert_eq!(d, vec![("wall-clock", 5)]);
}
