// Float-sort fixture: NaN-unsafe comparators built from partial_cmp.
// Expected: float-sort at lines 5, 6 (both patterns collapse per line).

fn naughty(v: &mut Vec<f32>) -> Option<f32> {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.iter().cloned().max_by(|a, b| a.partial_cmp(b).unwrap())
}
