// Trace-emission fixture: recording from inside a fan-out closure
// violates the single-threaded-orchestration trace contract. Expected:
// trace-emission at line 7. The orchestration-side call at line 9 is fine.

fn naughty(tracer: &mut Tracer, out: &mut [f32]) {
    par_rows(out, 4, |_row, _chunk| {
        tracer.instant("worker-side", 0, &[]);
    });
    tracer.instant("orchestration-side", 0, &[]);
}
