// Ambient-RNG fixture: randomness must come from seeded util::rng
// streams. Expected: ambient-rng at lines 5, 6.

fn naughty() -> u64 {
    let mut rng = thread_rng();
    let roll: u64 = rand::random();
    let _ = &mut rng;
    roll
}
