// Unwrap fixture. The golden test lints this under a pretend rust/src
// path. Expected: unwrap-audit at line 6 only — the poisoning unwrap at
// line 10 and the #[cfg(test)] unwrap at line 16 are exempt.

fn naughty(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

fn sanctioned(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

#[cfg(test)]
mod tests {
    fn also_fine(v: &[u32]) -> u32 {
        *v.first().unwrap()
    }
}
