// Unordered-iter fixture. The golden test lints this under the pretend
// path rust/src/server/bad_unordered_iter.rs, inside the ordered-output
// scope. Expected: unordered-iter at lines 6, 8.

fn naughty() -> Vec<u32> {
    use std::collections::HashMap;

    let m: HashMap<u32, u32> = [(1, 2)].into_iter().collect();
    m.values().copied().collect()
}
