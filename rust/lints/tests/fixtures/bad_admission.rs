// Admission-module fixture: shed bookkeeping through a HashMap inside
// the ordered-output scope (shed logs feed byte-identical reports), and
// a brownout trace emitted from inside a fan-out closure. Expected:
// unordered-iter at lines 7, 9; trace-emission at line 15.

fn naughty_shed_log() -> Vec<u64> {
    use std::collections::HashMap;

    let shed: HashMap<u64, &'static str> = [(3, "queue_full")].into_iter().collect();
    shed.keys().copied().collect()
}

fn naughty_brownout(tracer: &mut Tracer, delay_ewma: &mut [f32]) {
    par_rows(delay_ewma, 4, |_row, _chunk| {
        tracer.instant("brownout_enter", 0, &[]);
    });
    tracer.instant("brownout_exit", 0, &[]);
}
