// Wall-clock fixture: the rule fires on Instant::now, SystemTime, and
// .elapsed reads. Expected: wall-clock at lines 5, 6, 7.

fn naughty() {
    let t0 = std::time::Instant::now();
    let epoch = SystemTime::now();
    let waited = t0.elapsed();
    let _ = (epoch, waited);
}
