// Suppression fixture: a reasonless directive at line 8 (itself a
// violation, and it does NOT silence line 9), a leading directive at
// line 10 (covers the next code line, 11), and a trailing directive at
// line 12 (covers its own line).
// Expected: suppression at 8, wall-clock at 9; suppressed = 2.

fn intake() -> u64 {
    // pallas-lint: allow(wall-clock)
    let t1 = Instant::now();
    // pallas-lint: allow(wall-clock, reason = "real-time intake deadline")
    let t0 = Instant::now();
    let waited = t0.elapsed(); // pallas-lint: allow(wall-clock, reason = "measures the real wait")
    let _ = (t0, t1, waited);
    0
}
