"""AOT compile path: lower every stage x shape-bucket to HLO text and emit
the weight bundle, model config manifest, and golden fixtures.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONCE, at build time (`make artifacts`); the rust binary is
self-contained afterwards.

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bmw, model, weightgen
from .configs import DSV2_MINI, ModelSpec

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """jax lowered -> XLA HLO text via stablehlo (see module docstring).

    Single-output stages use ``return_tuple=False`` so their PJRT output is
    a plain array buffer the rust engine can feed straight into the next
    stage without a host round-trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _sd(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


#: Stages whose HLO root is a plain array (no tuple wrapper): their PJRT
#: output buffer can feed the next stage directly.
SINGLE_OUTPUT_STAGES = ("embed", "expert", "lm_head")


def stage_returns_tuple(name: str) -> bool:
    return not any(name.startswith(p) for p in SINGLE_OUTPUT_STAGES)


def stage_signatures(spec: ModelSpec):
    """Every (artifact name, python callable, example-arg specs).

    The artifact names and argument orders here are the binary contract with
    rust/src/runtime/artifacts.rs — change both together.
    """
    d, e, f, v, s = (spec.d_model, spec.n_experts, spec.d_ff,
                     spec.vocab_size, spec.max_seq)
    sigs = []

    def emb_fn(tokens, emb):
        return model.embed_stage(tokens, emb)

    for t in spec.token_buckets:
        sigs.append((f"embed_T{t}", emb_fn, [_sd((t,), I32), _sd((v, d))]))

    def prefill_fn(x, len_mask, ln1, wq, wk, wv, wo):
        return model.attn_prefill_stage(x, len_mask, ln1, wq, wk, wv, wo,
                                        spec=spec)

    sigs.append((
        "attn_prefill", prefill_fn,
        [_sd((s, d)), _sd((s,))] + [_sd((d,))] + [_sd((d, d))] * 4,
    ))

    def decode_fn(x, kc, vc, mask, ln1, wq, wk, wv, wo):
        return model.attn_decode_stage(x, kc, vc, mask, ln1, wq, wk, wv, wo,
                                       spec=spec, use_pallas=True)

    for b in spec.batch_buckets:
        sigs.append((
            f"attn_decode_B{b}", decode_fn,
            [_sd((b, d)), _sd((b, s, d)), _sd((b, s, d)), _sd((b, s))]
            + [_sd((d,))] + [_sd((d, d))] * 4,
        ))

    def router_fn(x, ln2, wg, rbias):
        return model.router_stage(x, ln2, wg, rbias, spec=spec,
                                  use_pallas=True)

    for t in spec.token_buckets:
        sigs.append((
            f"router_T{t}", router_fn,
            [_sd((t, d)), _sd((d,)), _sd((d, e)), _sd((e,))],
        ))

    def expert_fn(h, w1, w3, w2):
        return model.expert_stage(h, w1, w3, w2, use_pallas=True)

    for t in spec.token_buckets:
        sigs.append((
            f"expert_T{t}", expert_fn,
            [_sd((t, d)), _sd((d, f)), _sd((d, f)), _sd((f, d))],
        ))

    def head_fn(x, gain, emb):
        return model.lm_head_stage(x, gain, emb, spec=spec)

    for t in spec.token_buckets:
        sigs.append((
            f"lm_head_T{t}", head_fn,
            [_sd((t, d)), _sd((d,)), _sd((v, d))],
        ))
    return sigs


def emit_hlo(spec: ModelSpec, hlo_dir: str) -> dict:
    os.makedirs(hlo_dir, exist_ok=True)
    manifest = {}
    for name, fn, args in stage_signatures(spec):
        lowered = jax.jit(fn).lower(*args)
        tup = stage_returns_tuple(name)
        text = to_hlo_text(lowered, return_tuple=tup)
        rel = f"{name}.hlo.txt"
        with open(os.path.join(hlo_dir, rel), "w") as fh:
            fh.write(text)
        manifest[name] = {
            "file": rel,
            "num_args": len(args),
            "arg_shapes": [list(a.shape) for a in args],
            "tuple_output": tup,
        }
        print(f"  lowered {name}: {len(text)} chars")
    return manifest


def emit_goldens(spec: ModelSpec, w, out_path: str, seed: int = 11,
                 n_cases: int = 3, prompt_len: int = 12, n_steps: int = 8):
    """Reference decode traces for the rust integration tests.

    Regenerates with a shifted seed if any step's top-2 logit gap is < 0.05
    (so rust argmax comparison can't flip on fp reordering).
    """
    rng = np.random.default_rng(seed)
    cases = []
    domains = ["easy", "hard", "mixed"]
    attempts = 0
    while len(cases) < n_cases:
        dom = domains[len(cases) % len(domains)]
        half = spec.vocab_size // 2
        if dom == "easy":
            prompt = rng.integers(1, half, size=prompt_len)
        elif dom == "hard":
            prompt = rng.integers(half, spec.vocab_size, size=prompt_len)
        else:
            prompt = rng.integers(1, spec.vocab_size, size=prompt_len)
        prompt = prompt.astype(np.int32)
        toks, logits, traces = model.reference_decode(
            spec, w, prompt, n_steps, use_pallas=False)
        gaps = []
        for srow in logits:
            top2 = np.sort(srow)[-2:]
            gaps.append(float(top2[1] - top2[0]))
        attempts += 1
        if min(gaps) < 0.05 and attempts < 20:
            seed += 1
            rng = np.random.default_rng(seed)
            continue
        # Router fixture: layer-0 top-k of the first decode step.
        tr0 = traces[0]
        cases.append({
            "domain": dom,
            "prompt": prompt.tolist(),
            "gen_tokens": toks.tolist(),
            "logits": [[round(float(x), 6) for x in row] for row in logits],
            "min_top2_gap": min(gaps),
            "router_l0_step0_idx": tr0.layer_topk_idx[0][0].tolist(),
            "router_l0_step0_w": [round(float(x), 6)
                                  for x in tr0.layer_topk_w[0][0]],
            "router_l0_step0_tae": round(float(tr0.layer_tae[0][0]), 6),
        })
    with open(out_path, "w") as fh:
        json.dump({"spec": spec.name, "n_steps": n_steps, "cases": cases}, fh)
    print(f"  goldens: {len(cases)} cases -> {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()
    spec = DSV2_MINI
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    print("[aot] generating weights ...")
    w = weightgen.generate(spec, seed=args.seed)
    bmw.write_bmw(os.path.join(out, "weights.bmw"), w)

    print("[aot] lowering stages ...")
    manifest = emit_hlo(spec, os.path.join(out, "hlo"))

    cfg = {
        "spec": spec.to_json_dict(),
        "weights_file": "weights.bmw",
        "hlo_dir": "hlo",
        "artifacts": manifest,
        "weightgen": {
            "seed": args.seed,
            "family_size": weightgen.GenParams.family_size,
            "n_families": spec.n_experts // weightgen.GenParams.family_size,
        },
        "golden_file": "golden/decode.json",
    }
    with open(os.path.join(out, "model_config.json"), "w") as fh:
        json.dump(cfg, fh, indent=1)

    if not args.skip_goldens:
        print("[aot] generating golden fixtures ...")
        os.makedirs(os.path.join(out, "golden"), exist_ok=True)
        emit_goldens(spec, w, os.path.join(out, "golden", "decode.json"))
    print("[aot] done.")


if __name__ == "__main__":
    main()
