"""Synthetic dsv2-mini weights with engineered expert redundancy.

The paper's mechanism rests on three empirical regularities of trained MoE
models (paper §2.4, §3.2). We cannot download DeepSeek-V2-Lite in this
offline environment, so we *construct* weights that provably exhibit the same
regularities, then measure everything downstream rather than assuming it:

1. **Functional redundancy (Fig 4)** — experts are generated in families of
   ``family_size``: each expert's FFN weights are
   ``a * prototype(family) + b * noise`` with ``a^2 + b^2 = 1``, so
   within-family weight cosine similarity concentrates near ``a^2`` and
   buddy substitution inside a family is a bounded perturbation.
2. **Correlated routing / co-activation (Figs 7, 9)** — router columns for
   same-family experts share a family direction ``u_f`` the same way, so a
   token whose hidden state aligns with ``u_f`` gives high logits to the
   whole family: top-k sets co-activate within families.
3. **Heavy-tailed activation (Fig 6)** — per-expert router bias is drawn
   from an exponential, so a few "popular" experts dominate routing counts.

Domains: embedding rows for token ids in the *lower* half of the
vocabulary (the ``syn-e`` / ARC-Easy analogue) are aligned with the router
directions of the *most popular* expert families, so easy traffic
concentrates on head experts that any popularity-informed cache keeps
resident — few misses, high accuracy under substitution policies.
Upper-half rows (``syn-c`` / ARC-Challenge) stay generic, routing
diffusely across the expert pool including the offloaded tail — more
misses, more substitution pressure, lower accuracy. This reproduces the
paper's ARC-E > ARC-C ordering through a real mechanism.

Everything is deterministic in ``seed``.
"""

from typing import Dict

import numpy as np

from .configs import ModelSpec


class GenParams:
    """Tunables for the redundancy construction (defaults calibrated in
    python/tests/test_weightgen.py to produce the paper's regularities)."""

    family_size = 4          # experts per family; E/family_size families
    proto_mix = 0.92         # 'a' — within-family cosine ~ a^2 = 0.9025
    router_family_mix = 0.90 # family share of each router column direction
    router_scale = 4.0       # overall router logit gain
    pop_scale = 1.0          # exponential bias scale (activation skew)
    easy_mix = 0.6           # head-direction share of easy-domain embeddings
    head_frac = 0.25         # fraction of families counted as "head"
    attn_std_scale = 1.0     # attention projection scale multiplier
    expert_out_scale = 1.25  # down-projection damping (residual stability)


def _unit_rows(x: np.ndarray) -> np.ndarray:
    return x / np.linalg.norm(x, axis=0, keepdims=True)


def family_of(e: int, p: GenParams = GenParams) -> int:
    return e // p.family_size


def generate(spec: ModelSpec, seed: int = 7, p: GenParams = GenParams
             ) -> Dict[str, np.ndarray]:
    """Generate the full weight dict (bmw tensor names, see DESIGN.md)."""
    rng = np.random.default_rng(seed)
    d, f, e, v = spec.d_model, spec.d_ff, spec.n_experts, spec.vocab_size
    assert e % p.family_size == 0
    n_fam = e // p.family_size
    w: Dict[str, np.ndarray] = {}

    emb = rng.normal(size=(v, d)).astype(np.float32)
    w["embed"] = emb  # easy-domain rows rewritten after routers exist
    w["final_gain"] = np.ones(d, dtype=np.float32)

    head_dirs = []  # per-layer mean router direction of popular families

    a = p.proto_mix
    b = float(np.sqrt(1.0 - a * a))
    for l in range(spec.n_layers):
        pre = f"L{l}."
        w[pre + "ln1"] = np.ones(d, dtype=np.float32)
        w[pre + "ln2"] = np.ones(d, dtype=np.float32)
        s = p.attn_std_scale / np.sqrt(d)
        for name in ("wq", "wk", "wv", "wo"):
            w[pre + name] = (rng.normal(size=(d, d)) * s).astype(np.float32)

        # --- Router: family-correlated columns + popularity bias ---------
        u_fam = _unit_rows(rng.normal(size=(d, n_fam)))          # [D, n_fam]
        cols = np.empty((d, e), dtype=np.float64)
        for ei in range(e):
            fam = ei // p.family_size
            noise = rng.normal(size=d)
            noise /= np.linalg.norm(noise)
            c = p.router_family_mix * u_fam[:, fam] + \
                np.sqrt(1 - p.router_family_mix ** 2) * noise
            cols[:, ei] = c / np.linalg.norm(c)
        w[pre + "wg"] = (cols * p.router_scale).astype(np.float32)
        rbias = rng.exponential(p.pop_scale, size=e)
        w[pre + "rbias"] = rbias.astype(np.float32)

        # Head families for the easy domain: most popular by total bias.
        fam_pop = rbias.reshape(n_fam, p.family_size).sum(axis=1)
        n_head = max(1, int(round(n_fam * p.head_frac)))
        head = np.argsort(fam_pop)[-n_head:]
        hd = u_fam[:, head].mean(axis=1)
        head_dirs.append(hd / np.linalg.norm(hd))

        # --- Experts: prototype + perturbation families -------------------
        s1 = 1.0 / np.sqrt(d)
        s2 = p.expert_out_scale / np.sqrt(f)
        protos = {
            "w1": rng.normal(size=(n_fam, d, f)) * s1,
            "w3": rng.normal(size=(n_fam, d, f)) * s1,
            "w2": rng.normal(size=(n_fam, f, d)) * s2,
        }
        for ei in range(e):
            fam = ei // p.family_size
            for name, pr in protos.items():
                noise = rng.normal(size=pr.shape[1:]) * \
                    (s1 if name in ("w1", "w3") else s2)
                w[f"{pre}E{ei}.{name}"] = (
                    a * pr[fam] + b * noise).astype(np.float32)

    # Easy-domain embeddings: mix in the cross-layer mean head direction so
    # lower-vocab tokens keep steering toward popular (cached) experts
    # through the residual stream. Hard rows stay generic -> diffuse
    # routing that reaches the offloaded tail.
    hd = np.mean(head_dirs, axis=0)
    hd /= np.linalg.norm(hd)
    half = v // 2
    row_norm = np.linalg.norm(emb[half:], axis=1).mean()
    mix, keep = p.easy_mix, np.sqrt(1.0 - p.easy_mix ** 2)
    for i in range(half):
        r = emb[i] / np.linalg.norm(emb[i])
        r = mix * hd + keep * r
        emb[i] = (r / np.linalg.norm(r)) * row_norm
    w["embed"] = emb.astype(np.float32)
    return w


def expert_tensor_names(l: int, e: int):
    return [f"L{l}.E{e}.w1", f"L{l}.E{e}.w3", f"L{l}.E{e}.w2"]
