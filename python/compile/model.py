"""L2 — dsv2-mini stage functions and the pure-python reference model.

The forward pass is factored into the exact stage boundaries the rust
coordinator orchestrates (one AOT artifact per stage x shape bucket):

    embed -> [per layer: attn (prefill|decode) -> router -> {expert}xE] -> lm_head

Top-k selection, buddy gating/substitution, weighted combine, and residual
accumulation for the MoE output happen in rust (L3) — that is where the
paper's system lives. ``reference_*`` functions below replicate those L3
steps in python for golden-fixture generation and cross-layer validation.

Every stage takes ``interpret``-mode Pallas kernels (L1) when
``use_pallas=True`` (the AOT default) and the jnp oracles otherwise; pytest
asserts both paths agree.
"""

from functools import partial
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelSpec
from .kernels import ref
from .kernels.attention import attn_decode_core as pallas_attn_decode
from .kernels.expert_ffn import expert_ffn as pallas_expert_ffn
from .kernels.router import router as pallas_router

# --------------------------------------------------------------------------
# Stage functions (AOT-exported; weights are runtime parameters)
# --------------------------------------------------------------------------


def embed_stage(tokens, emb):
    """tokens: i32[T]; emb: [V, D] -> x [T, D]."""
    return jnp.take(emb, tokens, axis=0)


def _heads(x, n_heads):
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads)


def attn_prefill_stage(x, len_mask, ln1, wq, wk, wv, wo, *, spec: ModelSpec):
    """Full-prompt causal attention.

    x: [S, D]; len_mask: [S] -> (y [S, D] with residual, k [S, D], v [S, D]).
    Padding rows produce garbage y but are masked out downstream.
    """
    h = ref.rms_norm(x, ln1, spec.rms_eps)
    q = _heads(h @ wq, spec.n_heads)
    k = _heads(h @ wk, spec.n_heads)
    v = _heads(h @ wv, spec.n_heads)
    scale = 1.0 / np.sqrt(spec.head_dim)
    o = ref.attn_prefill_core(q, k, v, len_mask, scale)
    y = x + o.reshape(x.shape) @ wo
    return y, k.reshape(x.shape), v.reshape(x.shape)


def attn_decode_stage(x, k_cache, v_cache, pos_mask, ln1, wq, wk, wv, wo, *,
                      spec: ModelSpec, use_pallas: bool = True):
    """Single-step attention for B sequences.

    x: [B, D]; k_cache/v_cache: [B, S, D] (slots with pos_mask==0 ignored);
    pos_mask: [B, S]. The current token's K/V is appended logically inside
    the stage; rust writes the returned k_new/v_new into the cache after the
    call. Returns (y [B, D], k_new [B, D], v_new [B, D]).
    """
    b, d = x.shape
    s = k_cache.shape[1]
    h = ref.rms_norm(x, ln1, spec.rms_eps)
    q = (h @ wq).reshape(b, spec.n_heads, spec.head_dim)
    k_new = h @ wk
    v_new = h @ wv
    kc = jnp.concatenate(
        [k_cache.reshape(b, s, spec.n_heads, spec.head_dim),
         k_new.reshape(b, 1, spec.n_heads, spec.head_dim)], axis=1)
    vc = jnp.concatenate(
        [v_cache.reshape(b, s, spec.n_heads, spec.head_dim),
         v_new.reshape(b, 1, spec.n_heads, spec.head_dim)], axis=1)
    mask = jnp.concatenate([pos_mask, jnp.ones((b, 1), x.dtype)], axis=1)
    scale = 1.0 / np.sqrt(spec.head_dim)
    core = pallas_attn_decode if use_pallas else ref.attn_decode_core
    o = core(q, kc, vc, mask, scale)
    y = x + o.reshape(b, d) @ wo
    return y, k_new, v_new


def router_stage(x, ln2, wg, rbias, *, spec: ModelSpec,
                 use_pallas: bool = True):
    """x: [T, D] -> (h [T, D] normed MoE input, probs [T, E])."""
    if use_pallas:
        return pallas_router(x, ln2, wg, rbias, spec.rms_eps)
    return ref.router(x, ln2, wg, rbias, spec.rms_eps)


def expert_stage(h, w1, w3, w2, *, use_pallas: bool = True):
    """h: [T, D] -> y [T, D] for one expert over a routed token group."""
    if use_pallas:
        return pallas_expert_ffn(h, w1, w3, w2)
    return ref.expert_ffn(h, w1, w3, w2)


def lm_head_stage(x, final_gain, emb, *, spec: ModelSpec):
    """x: [T, D] -> logits [T, V] (tied embedding)."""
    h = ref.rms_norm(x, final_gain, spec.rms_eps)
    return h @ emb.T


# --------------------------------------------------------------------------
# Reference L3 logic (python mirror of the rust coordinator's math)
# --------------------------------------------------------------------------


def top_k_select(probs: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic top-k: by prob desc, index asc on ties.

    probs: [T, E] -> (idx [T, k] i64, weights [T, k] renormalized).
    The rust coordinator (model::route) implements the identical rule.
    """
    t, e = probs.shape
    # lexsort on (-prob, index): stable argsort of -probs is exactly that.
    order = np.argsort(-probs, axis=-1, kind="stable")
    idx = order[:, :k]
    w = np.take_along_axis(probs, idx, axis=-1)
    w = w / np.sum(w, axis=-1, keepdims=True)
    return idx, w


def tae(weights: np.ndarray, k: int) -> np.ndarray:
    """Token Activating Entropy (paper Eq. 1) from renormalized top-k
    weights: [T, k] -> [T] in [0, 1]."""
    safe = np.clip(weights, 1e-30, 1.0)
    wl = np.where(weights > 0, weights * np.log(safe), 0.0)
    return -np.sum(wl, axis=-1) / np.log(k)


class LayerWeights(NamedTuple):
    ln1: jnp.ndarray
    wq: jnp.ndarray
    wk: jnp.ndarray
    wv: jnp.ndarray
    wo: jnp.ndarray
    ln2: jnp.ndarray
    wg: jnp.ndarray
    rbias: jnp.ndarray
    experts: List[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]


def split_weights(spec: ModelSpec, w: Dict[str, np.ndarray]):
    """Group a flat bmw dict into per-layer structures (jnp arrays)."""
    emb = jnp.asarray(w["embed"])
    final_gain = jnp.asarray(w["final_gain"])
    layers = []
    for l in range(spec.n_layers):
        p = f"L{l}."
        experts = [
            tuple(jnp.asarray(w[f"{p}E{e}.{n}"]) for n in ("w1", "w3", "w2"))
            for e in range(spec.n_experts)
        ]
        layers.append(LayerWeights(
            ln1=jnp.asarray(w[p + "ln1"]), wq=jnp.asarray(w[p + "wq"]),
            wk=jnp.asarray(w[p + "wk"]), wv=jnp.asarray(w[p + "wv"]),
            wo=jnp.asarray(w[p + "wo"]), ln2=jnp.asarray(w[p + "ln2"]),
            wg=jnp.asarray(w[p + "wg"]), rbias=jnp.asarray(w[p + "rbias"]),
            experts=experts,
        ))
    return emb, final_gain, layers


def moe_combine(h, idx, wts, experts, use_pallas=False):
    """Reference MoE output: weighted sum of selected expert outputs.

    h: [T, D]; idx: [T, k]; wts: [T, k]. Runs each *distinct* expert over its
    token group exactly like the rust scheduler (group-by-expert), then
    scatter-adds — so golden fixtures exercise the same computation order
    class as the serving engine.
    """
    t, d = h.shape
    out = np.zeros((t, d), dtype=np.float32)
    h_np = np.asarray(h)
    for e in np.unique(idx):
        rows, slots = np.where(idx == e)
        grp = jnp.asarray(h_np[rows])
        w1, w3, w2 = experts[int(e)]
        y = np.asarray(expert_stage(grp, w1, w3, w2, use_pallas=use_pallas))
        out[rows] += wts[rows, slots][:, None] * y
    return out


class StepTrace(NamedTuple):
    """Routing telemetry for one model step (used for profiling fixtures)."""
    layer_topk_idx: List[np.ndarray]     # per layer [T, k] selected experts
    layer_topk_w: List[np.ndarray]       # per layer [T, k] renorm weights
    layer_tae: List[np.ndarray]          # per layer [T]


def reference_forward(spec: ModelSpec, w: Dict[str, np.ndarray],
                      tokens: np.ndarray, use_pallas: bool = False
                      ) -> Tuple[np.ndarray, StepTrace]:
    """Full prompt forward (prefill): tokens [S0] -> logits [S0, V].

    Mirrors the rust engine's prefill exactly: pad to max_seq for attention,
    run token-parallel stages over the full padded batch, mask at the end.
    """
    s0 = tokens.shape[0]
    s = spec.max_seq
    assert s0 <= s
    padded = np.zeros(s, dtype=np.int32)
    padded[:s0] = tokens
    len_mask = jnp.asarray((np.arange(s) < s0).astype(np.float32))
    emb, final_gain, layers = split_weights(spec, w)

    x = embed_stage(jnp.asarray(padded), emb)
    tr = StepTrace([], [], [])
    for lw in layers:
        x, _, _ = attn_prefill_stage(x, len_mask, lw.ln1, lw.wq, lw.wk,
                                     lw.wv, lw.wo, spec=spec)
        h, probs = router_stage(x, lw.ln2, lw.wg, lw.rbias, spec=spec,
                                use_pallas=use_pallas)
        idx, wts = top_k_select(np.asarray(probs), spec.top_k)
        tr.layer_topk_idx.append(idx[:s0])
        tr.layer_topk_w.append(wts[:s0])
        tr.layer_tae.append(tae(wts, spec.top_k)[:s0])
        moe = moe_combine(h, idx, wts, lw.experts, use_pallas=use_pallas)
        x = x + jnp.asarray(moe)
    logits = lm_head_stage(x, final_gain, emb, spec=spec)
    return np.asarray(logits)[:s0], tr


def reference_decode(spec: ModelSpec, w: Dict[str, np.ndarray],
                     prompt: np.ndarray, n_steps: int,
                     use_pallas: bool = False):
    """Greedy decode: returns (generated token ids [n_steps],
    per-step logits [n_steps, V], list of StepTrace)."""
    s = spec.max_seq
    emb, final_gain, layers = split_weights(spec, w)
    n_layers = spec.n_layers

    # KV caches: [L][1, S, D]
    kc = [np.zeros((1, s, spec.d_model), np.float32) for _ in range(n_layers)]
    vc = [np.zeros((1, s, spec.d_model), np.float32) for _ in range(n_layers)]

    # Prefill, recording K/V.
    s0 = prompt.shape[0]
    padded = np.zeros(s, dtype=np.int32)
    padded[:s0] = prompt
    len_mask = jnp.asarray((np.arange(s) < s0).astype(np.float32))
    x = embed_stage(jnp.asarray(padded), emb)
    for li, lw in enumerate(layers):
        x, k, v = attn_prefill_stage(x, len_mask, lw.ln1, lw.wq, lw.wk,
                                     lw.wv, lw.wo, spec=spec)
        kc[li][0, :s0] = np.asarray(k)[:s0]
        vc[li][0, :s0] = np.asarray(v)[:s0]
        h, probs = router_stage(x, lw.ln2, lw.wg, lw.rbias, spec=spec,
                                use_pallas=use_pallas)
        idx, wts = top_k_select(np.asarray(probs), spec.top_k)
        moe = moe_combine(h, idx, wts, lw.experts, use_pallas=use_pallas)
        x = x + jnp.asarray(moe)
    logits = np.asarray(lm_head_stage(x, final_gain, emb, spec=spec))
    next_tok = int(np.argmax(logits[s0 - 1]))

    out_tokens, out_logits, traces = [], [], []
    pos = s0
    for _ in range(n_steps):
        tok = np.asarray([next_tok], dtype=np.int32)
        xb = embed_stage(jnp.asarray(tok), emb)      # [1, D]
        pos_mask = jnp.asarray(
            (np.arange(s) < pos).astype(np.float32))[None, :]
        tr = StepTrace([], [], [])
        for li, lw in enumerate(layers):
            y, k_new, v_new = attn_decode_stage(
                xb, jnp.asarray(kc[li]), jnp.asarray(vc[li]), pos_mask,
                lw.ln1, lw.wq, lw.wk, lw.wv, lw.wo, spec=spec,
                use_pallas=use_pallas)
            kc[li][0, pos] = np.asarray(k_new)[0]
            vc[li][0, pos] = np.asarray(v_new)[0]
            h, probs = router_stage(y, lw.ln2, lw.wg, lw.rbias, spec=spec,
                                    use_pallas=use_pallas)
            idx, wts = top_k_select(np.asarray(probs), spec.top_k)
            tr.layer_topk_idx.append(idx)
            tr.layer_topk_w.append(wts)
            tr.layer_tae.append(tae(wts, spec.top_k))
            moe = moe_combine(h, idx, wts, lw.experts, use_pallas=use_pallas)
            xb = y + jnp.asarray(moe)
        lg = np.asarray(lm_head_stage(xb, final_gain, emb, spec=spec))[0]
        out_tokens.append(next_tok)
        next_tok = int(np.argmax(lg))
        out_logits.append(lg)
        traces.append(tr)
        pos += 1
    return np.asarray(out_tokens), np.asarray(out_logits), traces
