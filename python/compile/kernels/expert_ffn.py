"""L1 Pallas kernel: fused gated-SiLU expert FFN.

This is the compute hot-spot that buddy substitution feeds: one call runs a
single expert over a group of tokens that the rust coordinator routed to it.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):
  * the grid tiles the token axis; each program instance owns a (BT, D)
    activation block — the VMEM-resident working set;
  * all three projections (gate w1, up w3, down w2) stay resident across the
    block so the gated product never round-trips to HBM between stages
    (the fusion the paper's CUDA expert kernel gets from staying in
    registers/smem);
  * tile shapes are multiples of the 8x128 MXU/VPU lanes where the mini
    config allows (D=64, F=128).

Lowered with ``interpret=True`` — the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU behaviour is estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Token-block size. 128 tokens x 64 dims x 4B = 32 KiB activations per
#: block; with the three weight tiles (96 KiB) the working set is ~160 KiB,
#: comfortably inside a TPU core's ~16 MiB VMEM with double-buffering room.
DEFAULT_BLOCK_T = 128


def _ffn_kernel(h_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One token-block: o = (silu(h @ w1) * (h @ w3)) @ w2."""
    h = h_ref[...]
    g = h @ w1_ref[...]          # [BT, F] gate path (MXU matmul)
    u = h @ w3_ref[...]          # [BT, F] up path
    a = g * jax.nn.sigmoid(g) * u  # fused SiLU-gate, stays in VMEM
    o_ref[...] = a @ w2_ref[...]   # [BT, D] down projection


def expert_ffn(h, w1, w3, w2, *, block_t: int = DEFAULT_BLOCK_T,
               interpret: bool = True):
    """Run one expert over a token group.

    h:  [T, D] normed activations; w1/w3: [D, F]; w2: [F, D].
    T must be a multiple of block_t or smaller than it (single block).
    """
    t, d = h.shape
    f = w1.shape[1]
    bt = min(block_t, t)
    if t % bt != 0:
        raise ValueError(f"token count {t} not a multiple of block {bt}")
    grid = (t // bt,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),   # stream token blocks
            pl.BlockSpec((d, f), lambda i: (0, 0)),    # weights resident
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), h.dtype),
        interpret=interpret,
    )(h, w1, w3, w2)


@functools.lru_cache(maxsize=None)
def vmem_estimate(block_t: int, d: int, f: int, bytes_per_el: int = 4) -> dict:
    """Static VMEM footprint estimate for one program instance.

    Used by DESIGN.md §Perf to reason about real-TPU residency; not used at
    runtime.
    """
    act_in = block_t * d * bytes_per_el
    weights = (2 * d * f + f * d) * bytes_per_el
    inter = 2 * block_t * f * bytes_per_el  # gate + up paths
    act_out = block_t * d * bytes_per_el
    total = act_in + weights + inter + act_out
    return {
        "activations_in": act_in,
        "weights": weights,
        "intermediates": inter,
        "activations_out": act_out,
        "total": total,
        "fits_vmem_16mb": total < 16 * 1024 * 1024,
    }
