"""L1 Pallas kernel: masked single-query (decode) attention core.

The decode step is the serving hot loop: one query per sequence against that
sequence's KV cache. The grid assigns one program instance per sequence (the
TPU analogue of the paper's one-CUDA-block-per-token partitioning); each
instance holds its query, its (S, H, hd) cache slab, and the position mask in
VMEM, computes masked scores + stable softmax + weighted sum without leaving
the core.

QKV/output projections live in the L2 stage function (plain XLA matmuls fuse
fine there); the kernel owns the score/softmax/value contraction, which is
the part that would be memory-bound on HBM without explicit blocking.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, s_ref, o_ref):
    """One sequence: o = softmax(mask(q.k^T * scale)) @ v."""
    q = q_ref[0]            # [H, hd]
    k = k_ref[0]            # [S, H, hd]
    v = v_ref[0]            # [S, H, hd]
    mask = m_ref[0]         # [S]
    scale = s_ref[0]
    scores = jnp.einsum("hd,shd->hs", q, k) * scale
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask[None, :] > 0, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.einsum("hs,shd->hd", w, v)


def attn_decode_core(q, k, v, pos_mask, scale: float, *, interpret: bool = True):
    """q: [B,H,hd]; k,v: [B,S,H,hd]; pos_mask: [B,S] -> [B,H,hd]."""
    b, h, hd = q.shape
    s = k.shape[1]
    scale_arr = jnp.full((1,), scale, dtype=q.dtype)
    return pl.pallas_call(
        _decode_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, h, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s, h, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, pos_mask, scale_arr)
