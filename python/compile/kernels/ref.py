"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its oracle to float32 tolerance on all shapes (pytest +
hypothesis sweep in python/tests/). The oracles are also used by the L2
reference model when ``use_pallas=False``.
"""

import jax
import jax.numpy as jnp


def rms_norm(x, gain, eps=1e-5):
    """RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * gain."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def silu(x):
    return x * jax.nn.sigmoid(x)


def expert_ffn(h, w1, w3, w2):
    """Gated-SiLU expert FFN: (silu(h @ w1) * (h @ w3)) @ w2.

    h:  [T, D]   activations (already RMS-normed by the router stage)
    w1: [D, F]   gate projection
    w3: [D, F]   up projection
    w2: [F, D]   down projection
    returns [T, D]
    """
    return (silu(h @ w1) * (h @ w3)) @ w2


def router(x, gain, wg, bias, eps=1e-5):
    """MoE pre-norm + router softmax.

    x:    [T, D] residual-stream activations
    gain: [D]    RMSNorm gain for the MoE block input
    wg:   [D, E] router projection
    bias: [E]    per-expert popularity bias (weightgen skews this)
    returns (h [T, D] normed activations fed to experts,
             probs [T, E] full softmax over experts)
    """
    h = rms_norm(x, gain, eps)
    logits = h @ wg + bias
    probs = jax.nn.softmax(logits, axis=-1)
    return h, probs


def attn_decode_core(q, k, v, pos_mask, scale):
    """Masked single-query attention against a cached K/V window.

    q:        [B, H, hd]     current-step queries
    k, v:     [B, S, H, hd]  KV cache (padded to S = max_seq)
    pos_mask: [B, S]         1.0 for valid cache slots, 0.0 for padding
    returns   [B, H, hd]
    """
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(pos_mask[:, None, :] > 0, scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    # Fully-masked rows can't occur (the current token is always valid) but
    # keep the oracle total: softmax of all -inf would be nan; guard anyway.
    w = jnp.where(jnp.sum(pos_mask, axis=-1)[:, None, None] > 0, w, 0.0)
    return jnp.einsum("bhs,bshd->bhd", w, v)


def attn_prefill_core(q, k, v, len_mask, scale):
    """Causal masked self-attention over a full (padded) prompt.

    q, k, v:  [S, H, hd]
    len_mask: [S] 1.0 for real tokens, 0.0 for right-padding
    returns   [S, H, hd]
    """
    s = q.shape[0]
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    valid = causal[None, :, :] & (len_mask[None, None, :] > 0)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(valid, scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, v)
