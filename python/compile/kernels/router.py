"""L1 Pallas kernel: MoE pre-norm + router projection + softmax.

Produces both the RMS-normed activations (fed to the experts) and the full
expert probability vector per token. Top-k selection deliberately happens in
the rust coordinator (L3): expert choice is where the paper's buddy
substitution, gating, and cache logic intervene, so the boundary between
"model math" and "routing policy" sits exactly at this kernel's output.

The per-expert bias term carries the popularity skew that weightgen
engineers (Fig 6's heavy-tailed activation distribution).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128


def _router_kernel(x_ref, g_ref, wg_ref, b_ref, eps_ref, h_ref, p_ref):
    """One token-block: h = rmsnorm(x)*g ; p = softmax(h @ wg + b)."""
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    h = x * jax.lax.rsqrt(ms + eps_ref[0]) * g_ref[...]
    logits = h @ wg_ref[...] + b_ref[...]
    # Numerically-stable softmax in VMEM.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    h_ref[...] = h
    p_ref[...] = p


def router(x, gain, wg, bias, eps: float = 1e-5, *,
           block_t: int = DEFAULT_BLOCK_T, interpret: bool = True):
    """x: [T, D]; gain: [D]; wg: [D, E]; bias: [E] -> (h [T,D], p [T,E])."""
    t, d = x.shape
    e = wg.shape[1]
    bt = min(block_t, t)
    if t % bt != 0:
        raise ValueError(f"token count {t} not a multiple of block {bt}")
    grid = (t // bt,)
    eps_arr = jnp.full((1,), eps, dtype=x.dtype)
    return pl.pallas_call(
        _router_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, e), lambda i: (0, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t, e), x.dtype),
        ],
        interpret=interpret,
    )(x, gain, wg, bias, eps_arr)
