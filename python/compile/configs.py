"""Model specifications for the dsv2-mini family.

The paper evaluates DeepSeek-V2-Lite: 64 experts per MoE layer, top-6 gating.
We keep that *routing* configuration exactly (it is what the buddy mechanism
operates on) and shrink the dense dimensions so the full model serves on the
CPU PJRT client. See DESIGN.md §3 for the substitution rationale.
"""

from dataclasses import dataclass, asdict, field
from typing import List


@dataclass(frozen=True)
class ModelSpec:
    """Static architecture description shared by L1/L2/L3.

    Serialized to artifacts/model_config.json; the rust coordinator treats
    that file as the single source of truth for shapes and bucket ladders.
    """

    name: str = "dsv2-mini"
    vocab_size: int = 512
    d_model: int = 64
    n_heads: int = 4
    head_dim: int = 16
    n_layers: int = 12
    n_experts: int = 64
    top_k: int = 6
    d_ff: int = 128
    max_seq: int = 128
    rms_eps: float = 1e-5
    # Token-batch bucket ladder for token-parallel stages (embed, router,
    # expert_ffn, lm_head). Rust pads a T-token group up to the next bucket.
    token_buckets: List[int] = field(
        default_factory=lambda: [1, 2, 4, 8, 16, 32, 64, 128]
    )
    # Sequence-batch bucket ladder for the decode-attention stage.
    batch_buckets: List[int] = field(default_factory=lambda: [1, 2, 4, 8, 16, 32])

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim, "d_model mismatch"
        assert self.top_k <= self.n_experts
        assert self.max_seq in self.token_buckets, (
            "prefill runs through token-parallel stages at T=max_seq; "
            "max_seq must be a token bucket"
        )

    @property
    def expert_param_count(self) -> int:
        """f32 parameters in one expert (w1 + w3 + w2)."""
        return 3 * self.d_model * self.d_ff

    @property
    def expert_bytes(self) -> int:
        return 4 * self.expert_param_count

    def to_json_dict(self) -> dict:
        return asdict(self)


#: The configuration every artifact bundle and experiment uses.
DSV2_MINI = ModelSpec()

#: A tiny spec for fast unit tests (never AOT-exported).
TINY = ModelSpec(
    name="tiny",
    vocab_size=64,
    d_model=16,
    n_heads=2,
    head_dim=8,
    n_layers=3,
    n_experts=8,
    top_k=2,
    d_ff=32,
    max_seq=16,
    token_buckets=[1, 2, 4, 8, 16],
    batch_buckets=[1, 2, 4],
)
