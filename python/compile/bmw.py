"""BMW — the BuddyMoE Weights bundle format.

A trivial, dependency-free binary tensor container shared between the python
compile path (writer) and the rust coordinator (reader,
``rust/src/weights/format.rs``). Little-endian throughout.

Layout:
    magic   4 bytes  b"BMW1"
    count   u32      number of tensors
    per tensor:
        name_len u16, name utf-8 bytes
        ndim     u8,  dims u32 * ndim
        data     f32 * prod(dims)
"""

import struct
from typing import Dict

import numpy as np

MAGIC = b"BMW1"


def write_bmw(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            a = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes(order="C"))


def read_bmw(path: str) -> Dict[str, np.ndarray]:
    out = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out[name] = data
    return out
