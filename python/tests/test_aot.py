"""AOT lowering smoke tests: every stage lowers to parseable HLO text."""

import jax
import numpy as np
import pytest

from compile import aot
from compile.configs import TINY


@pytest.fixture(scope="module")
def sigs():
    return aot.stage_signatures(TINY)


def test_signature_coverage(sigs):
    names = [s[0] for s in sigs]
    for t in TINY.token_buckets:
        for stage in ("embed", "router", "expert", "lm_head"):
            assert f"{stage}_T{t}" in names
    for b in TINY.batch_buckets:
        assert f"attn_decode_B{b}" in names
    assert "attn_prefill" in names


@pytest.mark.parametrize("stage", ["embed_T2", "router_T4", "expert_T4",
                                   "lm_head_T2", "attn_decode_B2",
                                   "attn_prefill"])
def test_stage_lowers_to_hlo(sigs, stage):
    name, fn, args = next(s for s in sigs if s[0] == stage)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    assert "ENTRY" in text
    # All runtime args appear as parameters.
    assert text.count("parameter(") >= len(args)


def test_hlo_text_executes_in_python_pjrt(sigs):
    """Round-trip sanity: the emitted HLO for expert_T2 can be recompiled
    by the local XLA client and reproduces the stage output."""
    from jax._src.lib import xla_client as xc
    name, fn, args = next(s for s in sigs if s[0] == "expert_T2")
    rng = np.random.default_rng(0)
    concrete = [np.asarray(rng.normal(size=a.shape), np.float32)
                for a in args]
    want = np.asarray(fn(*concrete))
    lowered = jax.jit(fn).lower(*args)
    # interpret-mode pallas lowers to plain HLO ops -> must not contain
    # mosaic custom-calls (those would break the rust CPU client).
    text = aot.to_hlo_text(lowered, return_tuple=False)
    assert "mosaic" not in text.lower()
    got = np.asarray(jax.jit(fn)(*concrete))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_single_output_stage_classification():
    assert not aot.stage_returns_tuple("expert_T8")
    assert not aot.stage_returns_tuple("embed_T1")
    assert not aot.stage_returns_tuple("lm_head_T128")
    assert aot.stage_returns_tuple("router_T8")
    assert aot.stage_returns_tuple("attn_decode_B4")
    assert aot.stage_returns_tuple("attn_prefill")
