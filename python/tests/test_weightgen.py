"""Weight-generator invariants: the engineered regularities the paper's
mechanism needs must actually hold in the generated bundle (DESIGN.md §3)."""

import numpy as np
import pytest

from compile import weightgen
from compile.configs import TINY


@pytest.fixture(scope="module")
def w(tiny_weights):
    return tiny_weights


def _flat_expert(w, l, e):
    return np.concatenate([w[f"L{l}.E{e}.{n}"].ravel()
                           for n in ("w1", "w3", "w2")])


def _cos(a, b):
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))


def test_deterministic(tiny_spec):
    w1 = weightgen.generate(tiny_spec, seed=3)
    w2 = weightgen.generate(tiny_spec, seed=3)
    assert set(w1) == set(w2)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


def test_seed_changes_weights(tiny_spec):
    w1 = weightgen.generate(tiny_spec, seed=3)
    w2 = weightgen.generate(tiny_spec, seed=4)
    assert not np.array_equal(w1["embed"], w2["embed"])


def test_all_tensors_present(tiny_spec, w):
    assert "embed" in w and "final_gain" in w
    for l in range(tiny_spec.n_layers):
        for n in ("ln1", "ln2", "wq", "wk", "wv", "wo", "wg", "rbias"):
            assert f"L{l}.{n}" in w
        for e in range(tiny_spec.n_experts):
            for n in ("w1", "w3", "w2"):
                assert f"L{l}.E{e}.{n}" in w


def test_shapes(tiny_spec, w):
    s = tiny_spec
    assert w["embed"].shape == (s.vocab_size, s.d_model)
    assert w["L0.wg"].shape == (s.d_model, s.n_experts)
    assert w["L0.rbias"].shape == (s.n_experts,)
    assert w["L0.E0.w1"].shape == (s.d_model, s.d_ff)
    assert w["L0.E0.w2"].shape == (s.d_ff, s.d_model)


def test_within_family_similarity_exceeds_cross(tiny_spec, w):
    """Core redundancy property: same-family experts are far more similar in
    weight space than cross-family pairs (enables Fig 4 & substitution)."""
    fs = weightgen.GenParams.family_size
    within, cross = [], []
    for l in range(tiny_spec.n_layers):
        flats = [_flat_expert(w, l, e) for e in range(tiny_spec.n_experts)]
        for i in range(tiny_spec.n_experts):
            for j in range(i + 1, tiny_spec.n_experts):
                c = _cos(flats[i], flats[j])
                (within if i // fs == j // fs else cross).append(c)
    assert np.mean(within) > 0.8, f"within-family cos {np.mean(within)}"
    assert abs(np.mean(cross)) < 0.2, f"cross-family cos {np.mean(cross)}"


def test_router_family_correlation(tiny_spec, w):
    """Router columns of same-family experts point the same way."""
    fs = weightgen.GenParams.family_size
    wg = w["L0.wg"]
    within, cross = [], []
    for i in range(tiny_spec.n_experts):
        for j in range(i + 1, tiny_spec.n_experts):
            c = _cos(wg[:, i], wg[:, j])
            (within if i // fs == j // fs else cross).append(c)
    assert np.mean(within) > 0.6
    assert np.mean(within) > np.mean(cross) + 0.4


def test_popularity_bias_skew(tiny_spec, w):
    """Exponential bias ⇒ heavy tail: max bias well above median."""
    for l in range(tiny_spec.n_layers):
        b = w[f"L{l}.rbias"]
        assert b.min() >= 0
        assert b.max() > 2.0 * np.median(b)


def test_easy_domain_rows_share_head_direction(tiny_spec, w):
    """Easy-vocab rows share a common (head-family) direction component;
    hard rows stay generic."""
    half = tiny_spec.vocab_size // 2
    easy = w["embed"][:half]
    easy_n = easy / np.linalg.norm(easy, axis=1, keepdims=True)
    mean_dir = easy_n.mean(axis=0)
    align = easy_n @ (mean_dir / np.linalg.norm(mean_dir))
    hard = w["embed"][half:]
    hard_n = hard / np.linalg.norm(hard, axis=1, keepdims=True)
    hard_align = hard_n @ (mean_dir / np.linalg.norm(mean_dir))
    assert align.mean() > hard_align.mean() + 0.3


def test_expert_param_accounting(tiny_spec):
    s = tiny_spec
    assert s.expert_param_count == 3 * s.d_model * s.d_ff
    assert s.expert_bytes == 4 * s.expert_param_count
