import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.configs import TINY, DSV2_MINI  # noqa: E402
from compile import weightgen  # noqa: E402


@pytest.fixture(scope="session")
def tiny_spec():
    return TINY


@pytest.fixture(scope="session")
def mini_spec():
    return DSV2_MINI


@pytest.fixture(scope="session")
def tiny_weights(tiny_spec):
    return weightgen.generate(tiny_spec, seed=3)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
