"""L1 correctness: Pallas decode attention core vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as ka
from compile.kernels import ref

ATOL = 1e-5


def _mk(rng, b, s, h, hd):
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    lens = rng.integers(1, s + 1, size=b)
    mask = (np.arange(s)[None, :] < lens[:, None]).astype(np.float32)
    return q, k, v, jnp.asarray(mask)


@pytest.mark.parametrize("b", [1, 2, 4, 16])
def test_matches_ref(b):
    rng = np.random.default_rng(b)
    q, k, v, m = _mk(rng, b, 32, 4, 16)
    got = ka.attn_decode_core(q, k, v, m, 0.25)
    want = ref.attn_decode_core(q, k, v, m, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_mask_excludes_positions():
    """Changing masked-out K/V slots must not change the output."""
    rng = np.random.default_rng(3)
    q, k, v, _ = _mk(rng, 2, 16, 2, 8)
    mask = jnp.asarray(
        (np.arange(16)[None, :] < np.array([[5], [9]])).astype(np.float32))
    base = np.asarray(ka.attn_decode_core(q, k, v, mask, 0.3))
    k2 = np.asarray(k).copy()
    v2 = np.asarray(v).copy()
    k2[0, 5:] = 1e3
    v2[0, 5:] = -1e3
    k2[1, 9:] = 1e3
    v2[1, 9:] = -1e3
    pert = np.asarray(ka.attn_decode_core(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), mask, 0.3))
    np.testing.assert_allclose(base, pert, atol=ATOL)


def test_single_valid_position_returns_its_value():
    rng = np.random.default_rng(4)
    q, k, v, _ = _mk(rng, 1, 8, 2, 4)
    mask = jnp.asarray(np.eye(8, dtype=np.float32)[0][None, :])  # only slot 0
    out = np.asarray(ka.attn_decode_core(q, k, v, mask, 1.0))
    np.testing.assert_allclose(out[0], np.asarray(v)[0, 0], atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([4, 16, 128]),
    h=st.sampled_from([1, 4]),
    hd=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_matches_ref(b, s, h, hd, seed):
    rng = np.random.default_rng(seed)
    q, k, v, m = _mk(rng, b, s, h, hd)
    scale = 1.0 / np.sqrt(hd)
    got = np.asarray(ka.attn_decode_core(q, k, v, m, scale))
    want = np.asarray(ref.attn_decode_core(q, k, v, m, scale))
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_prefill_causal_ref_property():
    """Prefill oracle: position i must ignore positions > i."""
    rng = np.random.default_rng(8)
    s, h, hd = 8, 2, 4
    q = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    mask = jnp.ones((s,), jnp.float32)
    base = np.asarray(ref.attn_prefill_core(q, k, v, mask, 0.5))
    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    k2[5:], v2[5:] = 99.0, -99.0  # only affects rows >= 5
    pert = np.asarray(ref.attn_prefill_core(
        q, jnp.asarray(k2), jnp.asarray(v2), mask, 0.5))
    np.testing.assert_allclose(base[:5], pert[:5], atol=ATOL)
