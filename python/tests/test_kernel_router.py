"""L1 correctness: Pallas router (pre-norm + softmax) vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import router as kr

ATOL = 1e-5


def _mk(rng, t, d, e, logit_scale=4.0):
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    g = jnp.asarray(rng.uniform(0.5, 1.5, size=(d,)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(d, e)) * logit_scale / np.sqrt(d),
                     jnp.float32)
    b = jnp.asarray(rng.exponential(1.0, size=(e,)), jnp.float32)
    return x, g, wg, b


@pytest.mark.parametrize("t", [1, 2, 8, 64, 128])
def test_matches_ref(t):
    rng = np.random.default_rng(t)
    x, g, wg, b = _mk(rng, t, 64, 64)
    h1, p1 = kr.router(x, g, wg, b)
    h2, p2 = ref.router(x, g, wg, b)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=ATOL)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=ATOL)


def test_probs_are_distribution():
    rng = np.random.default_rng(5)
    x, g, wg, b = _mk(rng, 16, 32, 24)
    _, p = kr.router(x, g, wg, b)
    p = np.asarray(p)
    assert (p >= 0).all()
    np.testing.assert_allclose(p.sum(axis=-1), np.ones(16), atol=1e-5)


def test_softmax_stability_large_logits():
    """Stable softmax must survive large logits without overflow."""
    rng = np.random.default_rng(6)
    x, g, wg, b = _mk(rng, 4, 16, 8, logit_scale=500.0)
    _, p = kr.router(x, g, wg, b)
    assert np.isfinite(np.asarray(p)).all()


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 2, 4, 16]),
    d=st.sampled_from([8, 64]),
    e=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_matches_ref(t, d, e, seed):
    rng = np.random.default_rng(seed)
    x, g, wg, b = _mk(rng, t, d, e)
    h1, p1 = kr.router(x, g, wg, b)
    h2, p2 = ref.router(x, g, wg, b)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=ATOL)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=ATOL)


def test_bias_shifts_distribution():
    """A large bias on one expert must dominate routing."""
    rng = np.random.default_rng(7)
    x, g, wg, b = _mk(rng, 8, 16, 8)
    b = np.asarray(b).copy()
    b[3] += 50.0
    _, p = kr.router(x, g, wg, jnp.asarray(b))
    assert (np.argmax(np.asarray(p), axis=-1) == 3).all()
