"""BMW weight-bundle format round-trip (binary contract with rust)."""

import numpy as np
import pytest

from compile import bmw


def test_roundtrip(tmp_path, rng):
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b.c": rng.normal(size=(8,)).astype(np.float32),
        "L0.E1.w2": rng.normal(size=(2, 3, 4)).astype(np.float32),
    }
    p = str(tmp_path / "t.bmw")
    bmw.write_bmw(p, tensors)
    back = bmw.read_bmw(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_scalarish_and_empty_name_rejected_magic(tmp_path):
    p = str(tmp_path / "bad.bmw")
    with open(p, "wb") as f:
        f.write(b"NOPE")
    with pytest.raises(ValueError):
        bmw.read_bmw(p)


def test_f64_downcast(tmp_path):
    t = {"x": np.arange(6, dtype=np.float64).reshape(2, 3)}
    p = str(tmp_path / "t.bmw")
    bmw.write_bmw(p, t)
    back = bmw.read_bmw(p)
    assert back["x"].dtype == np.float32
    np.testing.assert_array_equal(back["x"], t["x"].astype(np.float32))


def test_layout_is_row_major(tmp_path):
    """The rust reader assumes C order; verify bytes match C order."""
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    p = str(tmp_path / "t.bmw")
    bmw.write_bmw(p, {"x": np.asfortranarray(x)})
    back = bmw.read_bmw(p)
    np.testing.assert_array_equal(back["x"], x)
