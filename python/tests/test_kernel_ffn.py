"""L1 correctness: Pallas expert FFN vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_ffn as ke
from compile.kernels import ref

ATOL = 1e-5


def _mk(rng, t, d, f):
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, f)) / np.sqrt(d), jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(d, f)) / np.sqrt(d), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(f, d)) / np.sqrt(f), jnp.float32)
    return h, w1, w3, w2


@pytest.mark.parametrize("t", [1, 2, 4, 8, 16, 128])
def test_matches_ref_buckets(t):
    rng = np.random.default_rng(t)
    h, w1, w3, w2 = _mk(rng, t, 64, 128)
    got = ke.expert_ffn(h, w1, w3, w2)
    want = ref.expert_ffn(h, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@pytest.mark.parametrize("block_t", [1, 2, 4, 8])
def test_grid_tiling_invariant(block_t):
    """Output must not depend on the token-block size."""
    rng = np.random.default_rng(9)
    h, w1, w3, w2 = _mk(rng, 8, 16, 32)
    got = ke.expert_ffn(h, w1, w3, w2, block_t=block_t)
    want = ref.expert_ffn(h, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_rejects_ragged_blocks():
    rng = np.random.default_rng(1)
    h, w1, w3, w2 = _mk(rng, 6, 8, 16)
    with pytest.raises(ValueError):
        ke.expert_ffn(h, w1, w3, w2, block_t=4)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 2, 4, 8, 16]),
    d=st.sampled_from([8, 16, 64]),
    f=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.01, 10.0),
)
def test_hypothesis_shapes_scales(t, d, f, seed, scale):
    """Property sweep: any bucket shape / weight scale matches the oracle."""
    rng = np.random.default_rng(seed)
    h, w1, w3, w2 = _mk(rng, t, d, f)
    h = h * scale
    got = np.asarray(ke.expert_ffn(h, w1, w3, w2))
    want = np.asarray(ref.expert_ffn(h, w1, w3, w2))
    np.testing.assert_allclose(got, want, atol=ATOL * max(1.0, scale ** 2))


def test_zero_input_zero_output():
    h = jnp.zeros((4, 16), jnp.float32)
    rng = np.random.default_rng(2)
    _, w1, w3, w2 = _mk(rng, 4, 16, 32)
    out = np.asarray(ke.expert_ffn(h, w1, w3, w2))
    np.testing.assert_array_equal(out, np.zeros_like(out))


def test_vmem_estimate_fits():
    est = ke.vmem_estimate(128, 64, 128)
    assert est["fits_vmem_16mb"]
    assert est["total"] == est["activations_in"] + est["weights"] + \
        est["intermediates"] + est["activations_out"]
