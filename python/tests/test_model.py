"""L2 stage composition and reference-model invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_pallas_and_ref_paths_agree(tiny_spec, tiny_weights):
    """The AOT path (pallas kernels) and the oracle path must produce the
    same forward pass — this is the L1<->L2 composition check."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, tiny_spec.vocab_size, size=6).astype(np.int32)
    lg_ref, _ = model.reference_forward(tiny_spec, tiny_weights, toks,
                                        use_pallas=False)
    lg_pal, _ = model.reference_forward(tiny_spec, tiny_weights, toks,
                                        use_pallas=True)
    np.testing.assert_allclose(lg_ref, lg_pal, atol=1e-4)


def test_top_k_select_deterministic_ties():
    probs = np.array([[0.3, 0.3, 0.2, 0.2]])
    idx, w = model.top_k_select(probs, 2)
    assert idx.tolist() == [[0, 1]]  # index asc on ties
    np.testing.assert_allclose(w, [[0.5, 0.5]])


def test_top_k_weights_renormalized(rng):
    probs = rng.dirichlet(np.ones(16), size=8)
    idx, w = model.top_k_select(probs, 4)
    np.testing.assert_allclose(w.sum(axis=-1), np.ones(8), atol=1e-6)
    # selected are the true top-4
    for r in range(8):
        top = set(np.argsort(-probs[r])[:4])
        assert set(idx[r]) == top


def test_tae_bounds_and_extremes():
    # uniform over k -> TAE = 1
    w = np.full((1, 4), 0.25)
    np.testing.assert_allclose(model.tae(w, 4), [1.0], atol=1e-6)
    # delta -> TAE = 0
    w = np.array([[1.0, 0.0, 0.0, 0.0]])
    np.testing.assert_allclose(model.tae(w, 4), [0.0], atol=1e-6)


def test_moe_combine_equals_dense_sum(tiny_spec, tiny_weights):
    """group-by-expert combine == direct per-token sum."""
    rng = np.random.default_rng(1)
    t, d = 5, tiny_spec.d_model
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    _, _, layers = model.split_weights(tiny_spec, tiny_weights)
    lw = layers[0]
    probs = rng.dirichlet(np.ones(tiny_spec.n_experts), size=t)
    idx, wts = model.top_k_select(probs, tiny_spec.top_k)
    got = model.moe_combine(h, idx, wts, lw.experts)
    want = np.zeros((t, d), np.float32)
    for ti in range(t):
        for kk in range(tiny_spec.top_k):
            e = int(idx[ti, kk])
            w1, w3, w2 = lw.experts[e]
            y = np.asarray(ref.expert_ffn(h[ti:ti + 1], w1, w3, w2))[0]
            want[ti] += wts[ti, kk] * y
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_decode_continues_prefill(tiny_spec, tiny_weights):
    """reference_decode's first generated token == argmax of prefill logits
    at the last prompt position."""
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, tiny_spec.vocab_size, size=5).astype(np.int32)
    logits, _ = model.reference_forward(tiny_spec, tiny_weights, prompt)
    toks, _, _ = model.reference_decode(tiny_spec, tiny_weights, prompt, 2)
    assert toks[0] == int(np.argmax(logits[-1]))


def test_prefill_padding_invariant(tiny_spec, tiny_weights):
    """Logits over the real prompt must not depend on padding content —
    i.e. forward(prompt) is the same for any prompt shorter than max_seq."""
    rng = np.random.default_rng(3)
    p5 = rng.integers(0, tiny_spec.vocab_size, size=5).astype(np.int32)
    lg5, _ = model.reference_forward(tiny_spec, tiny_weights, p5)
    lg5b, _ = model.reference_forward(tiny_spec, tiny_weights,
                                      np.concatenate([p5, p5[:3]]))
    np.testing.assert_allclose(lg5, lg5b[:5], atol=1e-4)


def test_trace_shapes(tiny_spec, tiny_weights):
    rng = np.random.default_rng(4)
    toks = rng.integers(0, tiny_spec.vocab_size, size=4).astype(np.int32)
    _, tr = model.reference_forward(tiny_spec, tiny_weights, toks)
    assert len(tr.layer_topk_idx) == tiny_spec.n_layers
    for li in range(tiny_spec.n_layers):
        assert tr.layer_topk_idx[li].shape == (4, tiny_spec.top_k)
        assert tr.layer_tae[li].shape == (4,)
        assert ((tr.layer_tae[li] >= 0) & (tr.layer_tae[li] <= 1.0001)).all()
