//! The accuracy/throughput trade-off frontier (paper §3.4 "deployment-time
//! trade-offs"): sweep the TAE threshold tau and replacement budget rho at
//! a fixed cache rate and print the frontier.
//!
//! Run: `cargo run --release --example sweep_tradeoff [-- --fast]`

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use buddymoe::buddy::BuddyProfile;
use buddymoe::config::{MissPolicy, ModelConfig, ServingConfig};
use buddymoe::eval::{
    build_requests, forced_agreement, oracle_run, profile_model, warm_rank_from_profile,
    TableSettings,
};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::server::Server;
use buddymoe::weights::WeightStore;

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = ModelConfig::load(&dir)?;
    let store = Arc::new(WeightStore::load(&cfg)?);

    let settings = TableSettings {
        cache_rate: 0.5,
        n_easy: if fast { 3 } else { 5 },
        n_hard: if fast { 3 } else { 5 },
        max_new: if fast { 8 } else { 12 },
        seed: 99,
        clock: buddymoe::util::clock::ClockMode::Virtual,
    };
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);
    let mut oracle = oracle_run(&cfg, store.clone(), build_requests(&cfg, &settings))?;
    oracle.sort_by_key(|r| r.id);

    println!("| tau | rho | accuracy | tok/s | substitutions |");
    println!("|---|---|---|---|---|");
    for &tau in &[0.5, 0.75, 0.9, 0.95, 0.99] {
        for rho in [Some(2usize), Some(3), None] {
            let mut scfg = ServingConfig::default();
            scfg.miss_policy = MissPolicy::Buddy;
            scfg.cache_rate = settings.cache_rate;
            scfg.tae_tau = tau;
            scfg.rho = rho;
            scfg.seed = settings.seed;
            let buddies =
                BuddyProfile::build(&pc, &vec![scfg.cft_alpha; cfg.n_layers], scfg.k_max, 1e-3, true)?;
            let engine = Engine::new(
                cfg.clone(),
                scfg,
                store.clone(),
                Some(buddies),
                Some(warm.clone()),
                EngineOptions {
                    clock: settings.clock,
                    record_logits: true,
                    ..Default::default()
                },
            )?;
            let mut server = Server::new(engine);
            let mut requests = build_requests(&cfg, &settings);
            for req in requests.iter_mut() {
                let o = oracle.iter().find(|r| r.id == req.id).unwrap();
                req.force_tokens = Some(o.predictions.clone());
            }
            let clock = server.engine.clock();
            let t0 = clock.now();
            let mut responses = server.run_offline(requests)?;
            let wall = clock.since(t0).max(1e-12);
            responses.sort_by_key(|r| r.id);
            let o_refs: Vec<_> = oracle.iter().collect();
            let s_refs: Vec<_> = responses.iter().collect();
            let acc = forced_agreement(&o_refs, &s_refs);
            println!(
                "| {tau} | {} | {acc:.3} | {:.2} | {} |",
                rho.map(|r| r.to_string()).unwrap_or_else(|| "inf".into()),
                server.metrics.tokens_out as f64 / wall,
                server.engine.counters.get("substitutions"),
            );
            server.engine.shutdown();
        }
    }
    Ok(())
}
