//! Tail latency under load: sweep (arrival process × offered load × miss
//! policy) on the virtual clock and report TTFT / TBT / e2e percentiles
//! plus queue depth per cell — the serving regime the offline table runs
//! can't see. The whole grid is a discrete-event simulation (milliseconds
//! of wall time) and byte-identical per seed.
//!
//! Run: `cargo run --release --example sweep_load [-- --fast]`
//! Works with or without artifacts (synthetic-family fallback); emits
//! machine-readable `BENCH_load.json` next to Cargo.toml (uploaded by CI
//! as a perf-trajectory artifact alongside `BENCH_hotpath.json`).

use std::path::Path;

use anyhow::Result;
use buddymoe::config::ServingConfig;
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::traffic::{
    cells_json, report_markdown, run_load_cell_traced, run_sweep, LoadSettings, ProcessKind,
    SweepSpec,
};
use buddymoe::util::json::{num, obj, s};

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");

    // Artifacts when built; otherwise the synthetic-family model (the
    // shared eval fallback), so the sweep runs anywhere.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, store) = buddymoe::eval::load_model_or_synthetic(&dir, 4242)?;
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let spec = SweepSpec {
        processes: vec![ProcessKind::Poisson, ProcessKind::Bursty, ProcessKind::Closed],
        // Under-loaded -> saturated: decode steps on the simulated compute
        // model cost single-digit milliseconds, so 64 rps of 8-token
        // requests is past the knee.
        loads_rps: vec![4.0, 16.0, 64.0],
        presets: vec!["original".into(), "buddy-rho3".into()],
        settings: LoadSettings {
            n_requests: if fast { 12 } else { 32 },
            max_new: 8,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            // Trace every cell: each BENCH_load.json cell then carries the
            // p99 request's stall attribution ("where did the time go").
            trace: true,
            interactive_share: 1.0,
        },
    };

    println!(
        "# Load sweep at c = {} (virtual clock, seed {}, {} requests/cell)\n",
        spec.settings.cache_rate, spec.settings.seed, spec.settings.n_requests
    );

    // One fully-traced reference cell (bursty arrivals near the knee on
    // the buddy preset): its Perfetto-loadable trace is the TRACE_load.json
    // artifact the docs walkthrough opens.
    {
        let mut scfg = ServingConfig::default().preset("buddy-rho3")?;
        scfg.cache_rate = spec.settings.cache_rate;
        scfg.seed = spec.settings.seed;
        let process = ProcessKind::Bursty.build(&cfg, &spec.settings, 16.0);
        let (_cell, trace) = run_load_cell_traced(
            &cfg,
            store.clone(),
            &pc,
            &warm,
            scfg,
            "buddy-rho3",
            16.0,
            process,
        )?;
        let tpath = Path::new(env!("CARGO_MANIFEST_DIR")).join("TRACE_load.json");
        std::fs::write(&tpath, &trace.chrome_json)?;
        println!(
            "wrote {} ({} finished requests traced)\n",
            tpath.display(),
            trace.attributions.len()
        );
    }

    let cells = run_sweep(&cfg, store, &pc, &warm, &spec)?;
    println!("{}", report_markdown(&cells));

    let json = obj(vec![
        ("model", s(&cfg.name)),
        ("cache_rate", num(spec.settings.cache_rate)),
        ("seed", num(spec.settings.seed as f64)),
        ("n_requests", num(spec.settings.n_requests as f64)),
        ("max_new", num(spec.settings.max_new as f64)),
        ("cells", cells_json(&cells)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_load.json");
    std::fs::write(&path, json.to_string() + "\n")?;
    println!("wrote {}", path.display());
    Ok(())
}
