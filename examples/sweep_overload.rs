//! Overload protection past the saturation knee: sweep (offered load ×
//! admission mode) under MMPP bursts with a mixed Interactive/Batch
//! population, on the virtual clock.
//!
//! Each load level runs twice per policy preset: once with admission
//! control disabled (`fifo` — the seed serving loop, whose queue grows
//! without bound past the knee and whose TTFT collapses for every
//! class), and once with the SLO gate (`slo` — bounded queue,
//! deadline-unmeetable shedding, priority batch composition, brownout
//! coupling into the degradation waterfall).
//!
//! The acceptance row: at offered load ≥ 1.5× the FIFO knee, the p99.9
//! TTFT of *admitted Interactive* traffic under `slo` stays within 2×
//! its SLO budget while the `fifo` rows collapse, with nonzero shed-rate
//! and brownout-dwell columns showing how the gate paid for it.
//!
//! Run: `cargo run --release --example sweep_overload [-- --fast]`
//! Works with or without artifacts (synthetic-family fallback); emits
//! machine-readable `BENCH_overload.json` next to Cargo.toml (uploaded
//! by CI alongside the other BENCH artifacts).

use std::path::Path;

use anyhow::Result;
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::traffic::{
    overload_cells_json, overload_report_markdown, run_overload_sweep, AdmissionMode,
    LoadSettings, OverloadSweep, ProcessKind,
};
use buddymoe::util::json::{num, obj, s};

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");

    // Artifacts when built; otherwise the synthetic-family model (the
    // shared eval fallback), so the sweep runs anywhere.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, store) = buddymoe::eval::load_model_or_synthetic(&dir, 4242)?;
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let spec = OverloadSweep {
        // The load sweep's knee sits between 16 and 64 rps for this
        // model; the top rows are well past 1.5× it, where the FIFO
        // queue grows without bound over the burst windows.
        loads_rps: vec![16.0, 64.0, 128.0],
        presets: vec!["buddy-rho3".into()],
        admissions: vec![AdmissionMode::Fifo, AdmissionMode::Slo],
        // MMPP bursts: 2× the offered rate while bursting, silent while
        // idle — the same average load as Poisson, much deeper queue
        // excursions, which is what admission control is for.
        process: ProcessKind::Bursty,
        interactive_ttft_slo_s: 0.25,
        batch_ttft_slo_s: 2.5,
        queue_cap: 32,
        settings: LoadSettings {
            n_requests: if fast { 24 } else { 64 },
            max_new: 8,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            // Trace every cell: each BENCH_overload.json row then
            // carries the p99 admitted-Interactive stall attribution.
            trace: true,
            // Mixed population: half the arrivals carry the tight
            // Interactive budget, half the loose Batch one.
            interactive_share: 0.5,
        },
    };

    println!(
        "# Overload sweep at c = {} (virtual clock, seed {}, {} requests/cell, \
         interactive share {}, SLO {}s/{}s, queue cap {})\n",
        spec.settings.cache_rate,
        spec.settings.seed,
        spec.settings.n_requests,
        spec.settings.interactive_share,
        spec.interactive_ttft_slo_s,
        spec.batch_ttft_slo_s,
        spec.queue_cap,
    );

    let rows = run_overload_sweep(&cfg, store, &pc, &warm, &spec)?;
    println!("{}", overload_report_markdown(&rows));

    let json = obj(vec![
        ("model", s(&cfg.name)),
        ("cache_rate", num(spec.settings.cache_rate)),
        ("seed", num(spec.settings.seed as f64)),
        ("n_requests", num(spec.settings.n_requests as f64)),
        ("max_new", num(spec.settings.max_new as f64)),
        ("interactive_share", num(spec.settings.interactive_share)),
        ("interactive_ttft_slo_s", num(spec.interactive_ttft_slo_s)),
        ("batch_ttft_slo_s", num(spec.batch_ttft_slo_s)),
        ("queue_cap", num(spec.queue_cap as f64)),
        ("rows", overload_cells_json(&rows)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_overload.json");
    std::fs::write(&path, json.to_string() + "\n")?;
    println!("wrote {}", path.display());
    Ok(())
}
