//! End-to-end serving driver (the DESIGN.md validation workload): load the
//! real AOT-compiled model, serve a batched request mix at several cache
//! rates, and report latency / throughput / accuracy-vs-oracle for the
//! BuddyMoE policy against the on-demand baseline.
//!
//! This is the "load a small model and serve batched requests" E2E proof
//! that all three layers compose; results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_offload [-- --fast]`

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use buddymoe::config::ModelConfig;
use buddymoe::eval::{
    oracle_run, profile_model, run_method, warm_rank_from_profile, MethodSpec, TableSettings,
};
use buddymoe::weights::WeightStore;

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = ModelConfig::load(&dir)?;
    let store = Arc::new(WeightStore::load(&cfg)?);

    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 64 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let methods = [
        MethodSpec::new("Original (on-demand)", "original"),
        MethodSpec::new("BuddyMoE rho=3", "buddy-rho3"),
    ];
    println!("| c | method | ACC-E | ACC-C | avg | tok/s | ttft-free stalls | subs |");
    println!("|---|---|---|---|---|---|---|---|");
    for &cache_rate in &[0.75, 0.5, 0.375] {
        let settings = TableSettings {
            cache_rate,
            n_easy: if fast { 3 } else { 6 },
            n_hard: if fast { 3 } else { 6 },
            max_new: if fast { 8 } else { 16 },
            seed: 42,
            clock: buddymoe::util::clock::ClockMode::Virtual,
        };
        let oracle = oracle_run(
            &cfg,
            store.clone(),
            buddymoe::eval::build_requests(&cfg, &settings),
        )?;
        for m in &methods {
            let base = buddymoe::config::ServingConfig::default();
            let row = run_method(&cfg, store.clone(), &pc, &warm, m, &base, &settings, &oracle)?;
            println!(
                "| {cache_rate} | {} | {:.3} | {:.3} | {:.3} | {:.2} | {} fetches | {} |",
                row.label, row.acc_easy, row.acc_hard, row.avg, row.tok_s, row.fetches,
                row.substitutions,
            );
        }
    }
    Ok(())
}
