//! Quickstart: load the AOT-compiled dsv2-mini model, profile it, build
//! buddy lists, and serve a handful of requests under memory pressure.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use buddymoe::buddy::BuddyProfile;
use buddymoe::config::{ModelConfig, ServingConfig};
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain, WorkloadGen};
use buddymoe::model::{Engine, EngineOptions};
use buddymoe::server::Server;
use buddymoe::weights::WeightStore;

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = ModelConfig::load(&dir)?;
    let store = Arc::new(WeightStore::load(&cfg)?);
    println!(
        "model: {} — {} layers x {} experts (top-{}), {:.1} MiB of expert weights",
        cfg.name,
        cfg.n_layers,
        cfg.n_experts,
        cfg.top_k,
        (cfg.total_experts() * cfg.expert_bytes()) as f64 / (1024.0 * 1024.0)
    );

    // 1. Offline phase: profile co-activations on a held-out corpus.
    println!("\n[1/3] profiling co-activations ...");
    let pc = profile_model(&cfg, store.clone(), 32, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    // 2. Build buddy lists with the CFT mechanism.
    let mut scfg = ServingConfig::default().preset("buddy-rho3")?;
    scfg.cache_rate = 0.5; // only half the experts fit on the "GPU"
    let alphas = vec![scfg.cft_alpha; cfg.n_layers];
    let buddies = BuddyProfile::build(&pc, &alphas, scfg.k_max, 1e-3, true)?;
    let sizes = buddies.list_sizes(0);
    println!(
        "[2/3] buddy lists built: layer-0 |B| mean {:.1} (cap {})",
        sizes.iter().sum::<usize>() as f64 / sizes.len() as f64,
        scfg.k_max
    );

    // 3. Serve under memory pressure with buddy substitution.
    println!("[3/3] serving 6 requests at cache rate c=0.5 ...\n");
    let engine = Engine::new(
        cfg.clone(),
        scfg,
        store,
        Some(buddies),
        Some(warm),
        EngineOptions::default(),
    )?;
    let mut server = Server::new(engine);
    let mut gen = WorkloadGen::new(&cfg, 123);
    gen.max_new = 12;
    let reqs = gen.requests(Domain::Mixed, 6, 0);
    let responses = server.run_offline(reqs)?;

    for r in &responses {
        println!(
            "request {:>2}: {} tokens, ttft {:.3}s, total {:.3}s -> {:?}",
            r.id,
            r.tokens.len(),
            r.ttft,
            r.total,
            &r.tokens[..4.min(r.tokens.len())]
        );
    }
    println!("\n{}", server.metrics.report());
    println!(
        "substitutions: {}  |  demand fetches: {}",
        server.engine.counters.get("substitutions"),
        server.engine.counters.get("fetches")
    );
    server.engine.shutdown();
    Ok(())
}
