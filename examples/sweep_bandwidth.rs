//! ROADMAP bandwidth sweep (the paper's Figure 8 axis): serve the same
//! workload at PCIe bandwidths 4–64 GB/s under `ClockMode::Virtual` and
//! print tok/s plus p99 decode-step latency per miss policy. The whole
//! sweep is a discrete-event simulation — milliseconds of wall time per
//! point — and shows where buddy substitution stops mattering: once the
//! link is fast enough, on-demand fetches are cheap and every policy
//! converges (in throughput and in the tail).
//!
//! Run: `cargo run --release --example sweep_bandwidth [-- --fast]`
//! Works with or without artifacts (synthetic-family fallback).

use std::path::Path;

use anyhow::Result;
use buddymoe::config::ServingConfig;
use buddymoe::eval::{
    build_requests, engine_with_config, profile_model, warm_rank_from_profile, TableSettings,
};
use buddymoe::model::EngineOptions;
use buddymoe::server::Server;
use buddymoe::util::clock::ClockMode;

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");

    // Artifacts when built; otherwise the synthetic-family model (the
    // shared eval fallback), so the sweep runs anywhere.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, store) = buddymoe::eval::load_model_or_synthetic(&dir, 4242)?;

    let settings = TableSettings {
        cache_rate: 0.5,
        n_easy: if fast { 3 } else { 6 },
        n_hard: if fast { 3 } else { 6 },
        max_new: if fast { 8 } else { 16 },
        seed: 42,
        clock: ClockMode::Virtual,
    };
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    println!(
        "# PCIe bandwidth sweep at c = {} (virtual clock, seed {})\n",
        settings.cache_rate, settings.seed
    );
    println!("| GB/s | policy | tok/s | p99 step ms | demand MB | substitutions | fetches |");
    println!("|---|---|---|---|---|---|---|");
    for bw_gbps in [4.0f64, 8.0, 16.0, 32.0, 64.0] {
        for preset in ["original", "random", "buddy-tight", "buddy-rho3"] {
            let mut scfg = ServingConfig::default().preset(preset)?;
            scfg.cache_rate = settings.cache_rate;
            scfg.pcie_bandwidth = bw_gbps * 1e9;
            scfg.seed = settings.seed;
            let engine = engine_with_config(
                &cfg,
                store.clone(),
                &pc,
                &warm,
                scfg,
                EngineOptions { clock: settings.clock, ..Default::default() },
            )?;
            let mut server = Server::new(engine);
            let clock = server.engine.clock();
            let t0 = clock.now();
            server.run_offline(build_requests(&cfg, &settings))?;
            let wall = clock.since(t0).max(1e-12);
            let demand_mb = server
                .engine
                .transfer_handle()
                .with_state(|st| st.pcie_stats().demand_bytes) as f64
                / (1024.0 * 1024.0);
            println!(
                "| {bw_gbps:.0} | {preset} | {:.2} | {:.2} | {demand_mb:.2} | {} | {} |",
                server.metrics.tokens_out as f64 / wall,
                server.metrics.step_latency.p(99.0) * 1e3,
                server.engine.counters.get("substitutions"),
                server.engine.counters.get("fetches"),
            );
            server.engine.shutdown();
        }
    }
    Ok(())
}
