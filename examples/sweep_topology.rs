//! Tail latency vs. expert-parallel fleet shape: sweep (device count ×
//! peer topology × replication factor × arrival process × miss policy) on
//! the virtual clock at a fixed offered load and report per-fleet-shape
//! tail-latency rows. Multi-device cells run with ψ's κ hop penalty live,
//! so buddy substitution is steered toward same-device buddies while
//! demand misses fan out over per-device host links and cross-device
//! dispatches queue on the contended peer links. Replicated cells
//! (replication_factor > 1) deal the popularity-ranked hot experts to
//! multiple homes — the p99 win under the bursty (MMPP) process is the
//! acceptance row.
//!
//! Run: `cargo run --release --example sweep_topology [-- --fast]`
//! Works with or without artifacts (synthetic-family fallback); emits
//! machine-readable `BENCH_topology.json` next to Cargo.toml (uploaded by
//! CI alongside `BENCH_hotpath.json` and `BENCH_load.json`).

use std::path::Path;

use anyhow::Result;
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::topology::TopologyKind;
use buddymoe::traffic::{
    run_topology_sweep, topology_cells_json, topology_report_markdown, LoadSettings, ProcessKind,
    TopologySweep,
};
use buddymoe::util::json::{num, obj, s};

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");

    // Artifacts when built; otherwise the synthetic-family model (the
    // shared eval fallback), so the sweep runs anywhere.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, store) = buddymoe::eval::load_model_or_synthetic(&dir, 4242)?;
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let spec = TopologySweep {
        device_counts: vec![1, 2, 4],
        topologies: vec![TopologyKind::FullyConnected, TopologyKind::Ring],
        replication_factors: vec![1, 2],
        processes: vec![ProcessKind::Poisson, ProcessKind::Bursty],
        presets: vec!["original".into(), "buddy-rho3".into()],
        // Past the single-device knee, so per-device host links have
        // something to parallelize.
        load_rps: 16.0,
        kappa: 0.25,
        settings: LoadSettings {
            n_requests: if fast { 12 } else { 32 },
            max_new: 8,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            // Untraced: BENCH_topology.json stays byte-identical to the
            // pre-trace golden.
            trace: false,
            interactive_share: 1.0,
        },
    };

    println!(
        "# Topology sweep at c = {} (virtual clock, seed {}, {} requests/cell, {} rps, kappa {})\n",
        spec.settings.cache_rate,
        spec.settings.seed,
        spec.settings.n_requests,
        spec.load_rps,
        spec.kappa
    );
    let rows = run_topology_sweep(&cfg, store, &pc, &warm, &spec)?;
    println!("{}", topology_report_markdown(&rows));

    let json = obj(vec![
        ("model", s(&cfg.name)),
        ("cache_rate", num(spec.settings.cache_rate)),
        ("seed", num(spec.settings.seed as f64)),
        ("n_requests", num(spec.settings.n_requests as f64)),
        ("max_new", num(spec.settings.max_new as f64)),
        ("load_rps", num(spec.load_rps)),
        ("kappa", num(spec.kappa)),
        ("rows", topology_cells_json(&rows)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_topology.json");
    std::fs::write(&path, json.to_string() + "\n")?;
    println!("wrote {}", path.display());
    Ok(())
}
