//! Availability under injected chaos: sweep (fault scenario × replication
//! factor × miss policy) on a 4-device ring at a fixed offered load, with
//! every fault landing as a deterministic discrete event on the virtual
//! clock. Scenarios come from `FaultPlan::scenario` — a fault-free
//! baseline, a device-down window, a host-link degradation, and a
//! peer-link flap burst — so every cell replays the identical seeded
//! workload and only the injected chaos differs.
//!
//! The acceptance row: with `replication_factor = 2` the fleet rides out
//! the device-down window with zero dropped experts (replica homes keep
//! serving, emergency promotions re-widen coverage) and near-baseline
//! availability, while the single-homed `replication_factor = 1` fleet
//! degrades into in-window substitution storms and tail blowup.
//!
//! Run: `cargo run --release --example sweep_faults [-- --fast]`
//! Works with or without artifacts (synthetic-family fallback); emits
//! machine-readable `BENCH_faults.json` next to Cargo.toml (uploaded by
//! CI alongside the other BENCH artifacts).

use std::path::Path;

use anyhow::Result;
use buddymoe::config::ServingConfig;
use buddymoe::eval::{profile_model, warm_rank_from_profile, Domain};
use buddymoe::fault::FaultPlan;
use buddymoe::topology::TopologyKind;
use buddymoe::traffic::{
    fault_cells_json, fault_report_markdown, run_fault_cell_traced, run_fault_sweep, FaultSweep,
    LoadSettings, ProcessKind,
};
use buddymoe::util::json::{num, obj, s};

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");

    // Artifacts when built; otherwise the synthetic-family model (the
    // shared eval fallback), so the sweep runs anywhere.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (cfg, store) = buddymoe::eval::load_model_or_synthetic(&dir, 4242)?;
    let pc = profile_model(&cfg, store.clone(), if fast { 16 } else { 48 }, 7777)?;
    let warm = warm_rank_from_profile(&pc);

    let spec = FaultSweep {
        scenarios: vec![
            "baseline".into(),
            "device-down".into(),
            "link-degrade".into(),
            "flap".into(),
            "lose-inflight".into(),
        ],
        // The acceptance fleet: 4 devices on a ring, so a down device
        // takes out a quarter of the home sets and peer reroutes matter.
        n_devices: 4,
        topology: TopologyKind::Ring,
        replication_factors: vec![1, 2],
        presets: vec!["buddy-rho3".into()],
        process: ProcessKind::Poisson,
        // Low enough that the run spans well past the 1–3 s fault
        // windows instead of draining before the chaos lands.
        load_rps: 4.0,
        // Deadline disabled: timed-out fetches fall back to lossless
        // transient rescues, so `dropped_slots` is structurally zero and
        // availability isolates the substitution cost of each scenario.
        transfer_deadline_s: 0.0,
        settings: LoadSettings {
            n_requests: if fast { 16 } else { 32 },
            max_new: 8,
            cache_rate: 0.5,
            domain: Domain::Mixed,
            seed: 42,
            // Trace every cell: each BENCH_faults.json row then carries
            // the p99 request's stall attribution.
            trace: true,
            interactive_share: 1.0,
        },
    };

    println!(
        "# Fault sweep on {} devices ({:?}) at c = {} (virtual clock, seed {}, {} requests/cell, {} rps)\n",
        spec.n_devices,
        spec.topology,
        spec.settings.cache_rate,
        spec.settings.seed,
        spec.settings.n_requests,
        spec.load_rps,
    );
    // One fully-traced reference cell (device-down on the single-homed
    // fleet — the worst-case degradation story): its Perfetto-loadable
    // trace is the TRACE_faults.json artifact, with fault epochs and every
    // degradation-waterfall arm visible as instants.
    {
        let mut scfg = ServingConfig::default().preset("buddy-rho3")?;
        scfg.cache_rate = spec.settings.cache_rate;
        scfg.seed = spec.settings.seed;
        scfg.n_devices = spec.n_devices;
        scfg.topology = spec.topology;
        scfg.fault_plan = FaultPlan::scenario("device-down")
            .expect("device-down is a built-in fault scenario");
        scfg.transfer_deadline_s = spec.transfer_deadline_s;
        let process = spec.process.build(&cfg, &spec.settings, spec.load_rps);
        let (_cell, _probe, _fault, trace) = run_fault_cell_traced(
            &cfg,
            store.clone(),
            &pc,
            &warm,
            scfg,
            "buddy-rho3",
            spec.load_rps,
            process,
        )?;
        let tpath = Path::new(env!("CARGO_MANIFEST_DIR")).join("TRACE_faults.json");
        std::fs::write(&tpath, &trace.chrome_json)?;
        println!(
            "wrote {} ({} finished requests traced)\n",
            tpath.display(),
            trace.attributions.len()
        );
    }

    let rows = run_fault_sweep(&cfg, store, &pc, &warm, &spec)?;
    println!("{}", fault_report_markdown(&rows));

    let json = obj(vec![
        ("model", s(&cfg.name)),
        ("n_devices", num(spec.n_devices as f64)),
        ("topology", s("ring")),
        ("cache_rate", num(spec.settings.cache_rate)),
        ("seed", num(spec.settings.seed as f64)),
        ("n_requests", num(spec.settings.n_requests as f64)),
        ("max_new", num(spec.settings.max_new as f64)),
        ("load_rps", num(spec.load_rps)),
        ("transfer_deadline_s", num(spec.transfer_deadline_s)),
        ("rows", fault_cells_json(&rows)),
    ]);
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_faults.json");
    std::fs::write(&path, json.to_string() + "\n")?;
    println!("wrote {}", path.display());
    Ok(())
}
