//! Structural analysis of expert redundancy — the data behind Figures 4,
//! 6, 7/9 — printed as ASCII heatmaps and distributions.
//!
//! Run: `cargo run --release --example buddy_analysis`

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use buddymoe::buddy::BuddyProfile;
use buddymoe::config::ModelConfig;
use buddymoe::eval::profile_model;
use buddymoe::profilecollect::expert_similarity_matrix;
use buddymoe::weights::WeightStore;

fn shade(x: f64) -> char {
    const RAMP: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '@'];
    let i = ((x.clamp(0.0, 1.0)) * (RAMP.len() - 1) as f64).round() as usize;
    RAMP[i]
}

fn heat(matrix: &[Vec<f64>], step: usize, title: &str) {
    println!("\n{title}");
    for row in matrix.iter().step_by(step) {
        let line: String = row.iter().step_by(step).map(|&x| shade(x)).collect();
        println!("  {line}");
    }
}

fn main() -> Result<()> {
    buddymoe::util::logging::init();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = ModelConfig::load(&dir)?;
    let store = Arc::new(WeightStore::load(&cfg)?);

    // --- Fig 4: weight-space similarity (layer 0) ------------------------
    let sim = expert_similarity_matrix(&cfg, &store, 0)?;
    let simf: Vec<Vec<f64>> = sim
        .iter()
        .map(|r| r.iter().map(|&x| x.max(0.0) as f64).collect())
        .collect();
    heat(&simf, 1, "Fig 4 — expert weight similarity, layer 0 (64x64, families of 4 visible on the diagonal blocks):");
    let fs = cfg.family_size;
    let (mut win, mut cross) = (0.0, 0.0);
    let (mut nw, mut nc) = (0, 0);
    for i in 0..cfg.n_experts {
        for j in (i + 1)..cfg.n_experts {
            if i / fs == j / fs {
                win += sim[i][j] as f64;
                nw += 1;
            } else {
                cross += sim[i][j] as f64;
                nc += 1;
            }
        }
    }
    println!(
        "  within-family mean cos {:.3} vs cross-family {:.3}",
        win / nw as f64,
        cross / nc as f64
    );

    // --- Figs 6 + 7/9: routing statistics --------------------------------
    let pc = profile_model(&cfg, store, 64, 7777)?;

    let l6 = (cfg.n_layers - 1).min(11);
    let acts = &pc.layer(l6).activations;
    let max = acts.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    println!("\nFig 6 — activation distribution, layer {l6} (heavy tail):");
    let mut ranked: Vec<(usize, f64)> = acts.iter().cloned().enumerate().collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (e, a) in ranked.iter().take(12) {
        println!("  expert {e:>2}: {} {a:.0}", "#".repeat((a / max * 50.0) as usize));
    }
    let total: f64 = acts.iter().sum();
    let top8: f64 = ranked.iter().take(8).map(|x| x.1).sum();
    println!("  -> top-8/64 experts take {:.1}% of routing events", 100.0 * top8 / total);

    let co = pc.layer(0);
    let maxc = co.binary.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    let com: Vec<Vec<f64>> = (0..cfg.n_experts)
        .map(|i| (0..cfg.n_experts).map(|j| co.m(i, j) / maxc).collect())
        .collect();
    heat(&com, 1, "Fig 7/9 — co-activation heatmap, layer 0 (sparse bright family blocks):");

    // --- Buddy list compactness (paper §3.3 report) ----------------------
    let profile = BuddyProfile::build(&pc, &vec![0.8; cfg.n_layers], 16, 1e-3, true)?;
    println!("\nBuddy list size distribution per layer (alpha=0.8, K_max=16):");
    for l in 0..cfg.n_layers {
        let sizes = profile.list_sizes(l);
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        let mx = sizes.iter().max().unwrap();
        println!("  layer {l:>2}: mean {mean:.1}, max {mx}");
    }
    Ok(())
}
